"""Fleet-scale open-loop load: latency knee, replica scaling, shed A/B.

Everything before this benchmark measured a handful of closed-loop
sessions on one host. Here the region simulator (``repro.fleet``)
drives N engine replicas — built from ONE ``build_engine`` spec over
mesh-placed parameters — with an open-loop Poisson session-arrival
process: arrivals do not wait for the system, so when the offered rate
exceeds fleet capacity the backlog (and every new session's
time-to-first-prediction) grows without bound. Three measurements:

  * **Load curve / knee** — offered rate swept as a fraction of the
    calibrated per-replica capacity; p50/p95/p99 TTFP per point. The
    *knee* is the highest offered rate whose p99 stays within
    ``KNEE_FACTOR`` x the lowest-rate p99 — beyond it open-loop
    queueing takes off.
  * **Replica scaling** — weak scaling: offered rate proportional to
    replica count (1/2/4/8) with total sessions held constant;
    sessions/s = finalized / makespan. Engine replicas are simulated
    serially on this host, each flush costing its own measured wall
    seconds, so the scaling read is per-replica-has-its-own-device.
  * **Shed-vs-queue A/B at 2x knee** — the same overload twice: once
    admitting everything (queue-to-death baseline), once with the
    deadline admission controller shedding new sessions to the
    on-glass degraded path. Shedding holds the ADMITTED p99 near the
    at-knee service level; without it the p99 blows past the knee.

Bit-parity is spot-checked every run: finalized fleet sessions must
match a per-event reference engine (same spec, same mesh-placed
pytree, same fixed batch bucket, one flush per event) at atol 0 —
fleet scale never buys drift. The fixed bucket (``ENGINE_KW``) is what
makes atol 0 honest: it pins every XLA call to one program shape, the
standard batch-invariance discipline.

Acceptance (checked by ``--smoke``):
  * ``passed_fleet_knee`` — with shedding at 2x knee, admitted-session
    p99 TTFP <= 1.5x the at-knee p99, while the admit-all baseline
    exceeds that bound;
  * ``passed_fleet_scaling`` — sessions/s grows >= 1.6x from 1 to 4
    replicas;
  * ``passed_fleet_parity`` — the atol-0 reference check above;
  * conservation — offered == admitted + shed, and degraded sessions
    emit ONLY ``degraded``-tagged partials.

-> artifacts/BENCH_fleet.json
"""
from __future__ import annotations

import os

# must precede any jax import: emulate a multi-device host so the fleet
# mesh has real devices to place parameters on (CI overrides with its
# own XLA_FLAGS; a pre-set value is respected)
if "--xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=8"
                               ).strip()

import json                                    # noqa: E402
from pathlib import Path                       # noqa: E402

import numpy as np                             # noqa: E402

from . import common as C                      # noqa: E402

ART = Path(__file__).resolve().parent / "artifacts"

KNEE_FACTOR = 3.0          # p99 blowup that defines "past the knee"
AB_BOUND = 1.5             # shed admitted p99 must stay <= this x knee p99
EVENTS_PER_SESSION = 6     # 1 text + 3 vitals + 2 scene (workload default)
TIME_SCALE = 0.01          # intra-session time compression: real incidents
                           # unfold over ~10 s (LAG_SCENARIOS onsets), which
                           # would make every makespan session-duration-bound;
                           # compressed, the fleet is serving-limited and the
                           # knee/scaling reads measure the engines

# Fixed batch bucket: every flush pads each modality group to exactly 8
# rows and coalesces at most 8 events, so EVERY XLA call in the sweep
# runs the one identical program shape. That is what makes the atol-0
# parity gate achievable at all: row-1 (GEMV) and row-N (GEMM) kernels
# legitimately differ at ~1e-6 on CPU, so a variably-shaped fleet could
# never bit-match a per-event reference. The padding FLOPs this buys
# parity with are real and show up in the capacity calibration — the
# benchmark measures the determinism-configured engine, not a free lunch.
ENGINE_KW = dict(batch_bucket_min=8, max_coalesce=8)


def _build(quick, seed=0):
    import jax
    from repro.configs.emsnet import tiny
    from repro.core import emsnet_zoo, split
    from repro.fleet import fleet_mesh, place_fleet_params

    # Always the tiny config, even in full mode: this benchmark reads
    # SERVING dynamics (queueing, coalescing, admission), where the
    # model only sets the service-time unit. The quick config's text
    # encoder costs ~0.3 s per padded flush on a 1/8th-host device,
    # which would price a single load-curve point at minutes; tiny
    # buys ~10x more offered sessions per wall second at identical
    # queueing behavior.
    cfg = tiny()
    zoo = emsnet_zoo(cfg)
    splits = {k: split(m) for k, m in zoo.items()}
    shared = zoo["text+vitals+scene"].init_fn(jax.random.PRNGKey(seed))
    params = {k: shared for k in zoo}
    mesh = fleet_mesh()
    placed, placement = place_fleet_params(params, mesh)
    payloads = C.sample_payloads(cfg, seed=seed + 1)
    payloads["vitals"] = payloads["vitals"][:, :5]
    C.warmup_engine_models(splits, placed, payloads)
    return cfg, splits, placed, payloads, placement


def _profile():
    from repro.core import ProfileTable
    return ProfileTable(base={"enc:text": 0.08, "enc:vitals": 0.01,
                              "enc:scene": 0.05, "tail": 0.02, "full": 0.16})


def _simulate(splits, placed, payloads, *, rate, n_sessions, n_replicas,
              admission=None, seed=0, profile=None):
    """One open-loop run at ``rate`` sessions/s, horizon sized so the
    offered-session count stays ~constant across rates (the wall cost
    of a point is bounded by its event count, not its rate).

    ``admission`` may be a zero-arg factory — stateful controllers must
    be rebuilt per run (warm + measured passes share one call site)."""
    from repro.fleet import RegionSim, generate_workload
    horizon = n_sessions / rate
    sessions = generate_workload(rate, horizon, seed=seed,
                                 time_scale=TIME_SCALE)
    if callable(admission):
        admission = admission()
    sim = RegionSim(splits, placed, n_replicas=n_replicas,
                    admission=admission, profile=profile,
                    engine_kw=dict(ENGINE_KW))
    sim.run(sessions, lambda sid, ev: payloads[ev.modality])
    return sim, sessions


def _point(splits, placed, payloads, **kw):
    """Warm-then-measure: the first pass compiles every bucketed batch
    shape this exact workload hits (pow2 row padding means intermediate
    coalesce sizes are distinct XLA programs — a compile landing inside
    a measured flush would poison that point's p99); the second pass
    replays the byte-identical workload on warm engines and is the one
    reported."""
    _simulate(splits, placed, payloads, **kw)
    return _simulate(splits, placed, payloads, **kw)


def _ttfp_stats(sim):
    xs = np.asarray(sorted(sim.ttfp.values()), float)
    if xs.size == 0:
        return {"n": 0}
    return {"n": int(xs.size),
            "p50_s": float(np.percentile(xs, 50)),
            "p95_s": float(np.percentile(xs, 95)),
            "p99_s": float(np.percentile(xs, 99))}


def _measure_mu(splits, placed, payloads, *, rate, n_sessions, seed):
    sim, _ = _simulate(splits, placed, payloads, rate=rate,
                       n_sessions=n_sessions, n_replicas=1, seed=seed)
    busy = sum(done - start for _, start, done, _ in sim.flush_log)
    events = sum(n for _, _, _, n in sim.flush_log)
    return (events / busy if busy > 0 else 1.0), sim._svc_est


def _calibrate(splits, placed, payloads, *, n_sessions, seed):
    """Per-replica capacity in sessions/s, measured twice on one
    replica as admitted events over summed flush wall seconds:

    * **light** — arrivals spaced out, flushes mostly single-event, so
      the per-event cost carries the full per-flush overhead. A
      conservative capacity: offered load below it is stable no matter
      how the batches fall. The scaling sweep runs here.
    * **saturated** — the whole workload arrives as a burst, backlog
      forces maximal coalescing, per-event cost amortizes to its floor.
      The true sustainable ceiling: offered load above it grows the
      backlog regardless of batching. The knee sweep is calibrated
      against THIS rate — coalescing is self-balancing (more backlog ->
      bigger batches -> higher throughput), so only rates above the
      saturated ceiling queue to death."""
    mu_light, svc_light = _measure_mu(splits, placed, payloads, rate=4.0,
                                      n_sessions=n_sessions, seed=seed)
    mu_sat, _ = _measure_mu(splits, placed, payloads, rate=200.0,
                            n_sessions=n_sessions, seed=seed)
    return {"service_rate_light_events_per_s": mu_light,
            "service_rate_saturated_events_per_s": mu_sat,
            "svc_est_s": svc_light,
            "capacity_light_sessions_per_s": mu_light / EVENTS_PER_SESSION,
            "capacity_saturated_sessions_per_s": mu_sat / EVENTS_PER_SESSION}


def _parity_check(sim, sessions, splits, placed, payloads, *, limit=4):
    """Finalized fleet sessions vs a per-event reference engine — same
    spec and the same FIXED batch bucket (``ENGINE_KW``), driven one
    flush per event — at atol 0. Equal bucket on both sides is load-
    bearing: it pins both to the identical padded program shape, so a
    session's row cannot depend on what else coalesced around it.
    Returns the number of sessions checked (raises on mismatch)."""
    from repro.serving.api import build_engine
    checked = 0
    for s in sessions:
        if checked >= limit:
            break
        got = sim.final_outputs(s.sid)
        if got is None:
            continue
        ref = build_engine(splits, placed, "batch+stream",
                           share_encoders=True, deadline_s=None,
                           **ENGINE_KW)
        preds = []
        for ev in s.events:
            ref.submit(s.sid, ev, payloads[ev.modality])
            preds += ref.flush().predictions
        want = next(p.outputs for p in reversed(preds)
                    if p.kind == "final")
        for k in want:
            np.testing.assert_array_equal(np.asarray(got[k]),
                                          np.asarray(want[k]))
        checked += 1
    return checked


def run(quick=True, *, smoke=False, seed=0):
    from repro.fleet import AdmissionController, AdmissionPolicy

    cfg, splits, placed, payloads, placement = _build(quick or smoke,
                                                      seed=seed)
    # offered sessions per sweep point: the overload points must offer
    # enough work for the backlog to integrate well past the light-load
    # p99 (excess-drain ~ n_point x events x (1/mu_sat - 1/offered)),
    # or the curve never shows a knee at any rate
    n_point = 40 if smoke else 64

    # ---- warmup passes: compile every engine path the sims will hit,
    # spaced arrivals (single-event flushes) AND a burst (coalesced
    # bucketed batch shapes)
    _simulate(splits, placed, payloads, rate=2.0, n_sessions=6,
              n_replicas=1, seed=seed)
    _simulate(splits, placed, payloads, rate=200.0, n_sessions=n_point,
              n_replicas=1, seed=seed)

    # ---- capacity calibration ---------------------------------------
    cal = _calibrate(splits, placed, payloads, n_sessions=n_point,
                     seed=seed)
    cap = cal["capacity_saturated_sessions_per_s"]

    # ---- load curve over 2 replicas: offered = frac x the saturated
    # fleet capacity
    # fracs are of the SATURATED ceiling; the low end must sit well
    # below the LIGHT (single-event-flush) capacity too, or the whole
    # curve is queue-dominated and flat — with the fixed 8-row bucket
    # light capacity is roughly a third of saturated
    n_curve = 2
    fracs = ((0.15, 0.5, 1.0, 2.0) if smoke
             else (0.15, 0.3, 0.6, 1.0, 1.5, 2.0))
    curve = []
    for frac in fracs:
        rate = frac * cap * n_curve
        # above-capacity points offer proportionally MORE sessions:
        # open-loop blowup is backlog integrated over the arrival
        # window, so a constant session count would cap the excess
        # drain at one horizon and flatten the knee away; the extra
        # sessions are nearly free there (max coalescing)
        n_sess = int(round(n_point * max(1.0, frac)))
        sim, _ = _point(splits, placed, payloads, rate=rate,
                        n_sessions=n_sess, n_replicas=n_curve,
                        seed=seed + 1)
        st = _ttfp_stats(sim)
        rep = sim.report()
        curve.append({"offered_x_capacity": frac,
                      "offered_sessions_per_s": rate,
                      "sessions": rep["sessions_offered"],
                      "residual_drain_s": (rep["makespan_s"]
                                           - sim._last_arrival),
                      "ttfp": st})
    base_p99 = min(c["ttfp"]["p99_s"] for c in curve if c["ttfp"]["n"])
    knee = None
    for c in curve:
        if c["ttfp"].get("p99_s", np.inf) <= KNEE_FACTOR * base_p99:
            knee = c
    knee_rate = knee["offered_sessions_per_s"]
    knee_p99 = knee["ttfp"]["p99_s"]

    # ---- shed-vs-queue A/B at 2x knee -------------------------------
    over_rate = 2.0 * knee_rate
    n_over = 2 * n_point       # sustained overload: see the curve note
    sim_q, _ = _point(splits, placed, payloads, rate=over_rate,
                      n_sessions=n_over, n_replicas=n_curve,
                      seed=seed + 2)
    q_stats = _ttfp_stats(sim_q)

    deadline = knee_p99
    ctrl = lambda: AdmissionController(  # noqa: E731 - rebuilt per pass
        AdmissionPolicy(deadline_s=deadline, enter_frac=1.0, exit_frac=0.5),
        n_curve)
    sim_s, sess_s = _point(splits, placed, payloads, rate=over_rate,
                           n_sessions=n_over, n_replicas=n_curve,
                           admission=ctrl, seed=seed + 2,
                           profile=_profile())
    s_stats = _ttfp_stats(sim_s)
    s_report = sim_s.report()
    # conservation + degraded-only-partials invariants
    assert (s_report["sessions_offered"]
            == s_report["sessions_admitted"] + s_report["sessions_shed"])
    assert all(r.kind == "partial" and r.degraded
               for r in sim_s.glass.records)
    shed_ok = (s_stats.get("p99_s", np.inf) <= AB_BOUND * knee_p99)
    queue_blows = (q_stats.get("p99_s", 0.0) > AB_BOUND * knee_p99)
    passed_knee = bool(shed_ok and queue_blows)

    # ---- parity spot-check (atol 0) ---------------------------------
    parity_n = _parity_check(sim_s, sess_s, splits, placed, payloads)

    # ---- weak scaling: offered ~ replicas, constant total sessions --
    replica_counts = (1, 2, 4) if smoke else (1, 2, 4, 8)
    scale_frac = 0.7
    cap_light = cal["capacity_light_sessions_per_s"]
    scaling = []
    for r in replica_counts:
        # weak scaling: offered ~ replicas, below the CONSERVATIVE
        # (light-load) per-replica capacity so every config is stable
        # and the read is arrival-limited throughput
        rate = scale_frac * cap_light * r
        sim, _ = _point(splits, placed, payloads, rate=rate,
                        n_sessions=2 * n_point,  # constant total work
                        n_replicas=r, seed=seed + 3)
        rep = sim.report()
        sps = (rep["sessions_finalized"] / rep["makespan_s"]
               if rep["makespan_s"] > 0 else 0.0)
        scaling.append({"replicas": r,
                        "offered_sessions_per_s": rate,
                        "sessions_finalized": rep["sessions_finalized"],
                        "makespan_s": rep["makespan_s"],
                        "sessions_per_s": sps})
    by_r = {s["replicas"]: s["sessions_per_s"] for s in scaling}
    ratio_1_4 = by_r[4] / by_r[1] if by_r.get(1) else 0.0
    passed_scaling = bool(ratio_1_4 >= 1.6)

    result = {
        "config": {"quick": bool(quick or smoke), "smoke": bool(smoke),
                   "sessions_per_point": n_point, "seed": seed,
                   "events_per_session": EVENTS_PER_SESSION,
                   "knee_factor": KNEE_FACTOR, "ab_bound": AB_BOUND},
        "placement": placement,
        "calibration": cal,
        "load_curve": curve,
        "knee": {"offered_sessions_per_s": knee_rate,
                 "offered_x_capacity": knee["offered_x_capacity"],
                 "p99_ttfp_s": knee_p99},
        "ab_at_2x_knee": {
            "offered_sessions_per_s": over_rate,
            "deadline_s": deadline,
            "admit_all": {"ttfp": q_stats,
                          "report": sim_q.report()},
            "shed": {"ttfp_admitted": s_stats,
                     "report": s_report},
        },
        "scaling": scaling,
        "scaling_ratio_1_to_4": ratio_1_4,
        "parity_checked_sessions": parity_n,
        "passed_fleet_knee": passed_knee,
        "passed_fleet_scaling": passed_scaling,
        "passed_fleet_parity": bool(parity_n > 0),
        "fleet_metrics": sim_s.fleet_metrics().snapshot(),
    }

    ART.mkdir(parents=True, exist_ok=True)
    name = "BENCH_fleet.smoke.json" if smoke else "BENCH_fleet.json"
    (ART / name).write_text(json.dumps(result, indent=2))

    C.csv_row("fleet_knee_p99_ttfp", knee_p99 * 1e6,
              f"knee_rate={knee_rate:.2f}/s;"
              f"shed_p99={s_stats.get('p99_s', float('nan')):.3f}s;"
              f"queue_p99={q_stats.get('p99_s', float('nan')):.3f}s")
    C.csv_row("fleet_sessions_per_s_4r", by_r[4] * 1e6,
              f"ratio_1_to_4={ratio_1_4:.2f}x;"
              f"shed_sessions={s_report['sessions_shed']}")

    if smoke:
        if not passed_knee:
            raise SystemExit(
                "fleet knee gate failed: shed p99 "
                f"{s_stats.get('p99_s')} vs bound {AB_BOUND * knee_p99:.3f} "
                f"(queue p99 {q_stats.get('p99_s')})")
        if not passed_scaling:
            raise SystemExit(
                f"fleet scaling gate failed: 1->4 replicas gives "
                f"{ratio_1_4:.2f}x sessions/s (need >= 1.6x)")
    return result


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="small run + assert knee/scaling/parity gates")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    r = run(quick=not args.full, smoke=args.smoke)
    print(json.dumps({k: r[k] for k in
                      ("knee", "scaling_ratio_1_to_4",
                       "passed_fleet_knee", "passed_fleet_scaling",
                       "passed_fleet_parity")}, indent=2))
