"""Paper Table 4: 3-modal EMSNet fine-tuned with vs without progressive
modality integration (PMI) on the small 3-modal D2 (paper: 3,005 samples
vs 123,803 in D1 — 2 orders of magnitude). Reproduced claim: PMI >=
from-scratch fine-tuning on protocol/medicine accuracy when D2 is tiny.
"""
from __future__ import annotations

import time

from . import common as C
from .table3_accuracy import _fmt


def run(quick=True):
    from repro.data import synthetic_nemsis as D
    from repro.training import emsnet_trainer as ET

    cfg = C.emsnet_cfg(quick, train=True)
    n1, n2 = (3000, 400) if quick else (20000, 1500)
    steps1, steps2 = (150, 80) if quick else (600, 300)

    d1 = D.generate(cfg, n1, seed=0)
    tr1, _, _ = D.splits(d1)
    p2, _ = ET.train(cfg, D.loader(tr1, 64, modalities=("text", "vitals")),
                     modalities=("text", "vitals"), steps=steps1)

    d2 = D.generate(cfg, n2, seed=7, modal3=True)
    tr2, _, te2 = D.splits(d2)
    rows = []

    t0 = time.time()
    p_pmi, _ = ET.pmi_finetune(cfg, p2, D.loader(tr2, 32), steps=steps2)
    m_pmi = ET.evaluate(p_pmi, cfg, te2, ("text", "vitals", "scene"))
    rows.append(C.csv_row("table4_pmi", (time.time() - t0) * 1e6, _fmt(m_pmi)))

    t0 = time.time()
    p_scr, _ = ET.train(cfg, D.loader(tr2, 32),
                        modalities=("text", "vitals", "scene"), steps=steps2)
    m_scr = ET.evaluate(p_scr, cfg, te2, ("text", "vitals", "scene"))
    rows.append(C.csv_row("table4_scratch", (time.time() - t0) * 1e6,
                          _fmt(m_scr)))

    assert m_pmi["protocol_top1"] >= m_scr["protocol_top1"] - 0.02, \
        "PMI must not lose to scratch on tiny D2 (paper Table 4)"
    return rows


if __name__ == "__main__":
    run(quick=False)
