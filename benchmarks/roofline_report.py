"""Roofline report (deliverable g): the full per-(arch x shape x mesh)
table from the dry-run artifacts — three roofline terms, dominant
bottleneck, useful-FLOPs ratio, bytes/device.
"""
from __future__ import annotations

import json
from pathlib import Path

from . import common as C

ART = Path(__file__).resolve().parent / "artifacts" / "dryrun"


def load(mesh="single"):
    recs = []
    for p in sorted(ART.glob(f"*_{mesh}.json")):
        r = json.loads(p.read_text())
        if r.get("ok"):
            recs.append(r)
    return recs


def run(quick=True, mesh="single"):
    rows = []
    if not ART.exists():
        print("roofline_report,0,no-artifacts (run repro.launch.dryrun --all)")
        return rows
    recs = load(mesh)
    for r in recs:
        roof = r["roofline"]
        dom = roof["bottleneck"]
        t_step = max(roof["t_compute_s"], roof["t_memory_s"],
                     roof["t_collective_s"])
        hbm_gb = (r.get("argument_size_in_bytes", 0)
                  + r.get("output_size_in_bytes", 0)
                  + r.get("temp_size_in_bytes", 0)) / 2**30
        rows.append(C.csv_row(
            f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}",
            t_step * 1e6,
            f"bottleneck={dom};tc={roof['t_compute_s']:.3g};"
            f"tm={roof['t_memory_s']:.3g};tcoll={roof['t_collective_s']:.3g};"
            f"useful={roof['useful_flops_ratio']:.3f};"
            f"mem_gb_per_dev={hbm_gb:.2f}"))
    return rows


if __name__ == "__main__":
    run(quick=False, mesh="single")
    run(quick=False, mesh="multi")
