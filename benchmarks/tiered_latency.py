"""Fig. 15-style offloading speedups from the LIVE tiered runtime.

``benchmarks/fig15_offloading.py`` replays the paper's offloading
comparison through the closed-form decision rule; this benchmark runs
the actual ``TieredEMSServe`` split-serving runtime instead — live
per-event decisions through the heartbeat monitor, byte-accounted
feature transport on in-order links, per-tier busy clocks — and sweeps
the paper's bandwidth regimes:

  * **static_{0,5,10,20,30}m** — scenario 2: NLOS WiFi at a fixed
    glass<->edge distance;
  * **mobility** — scenario 3: the EMT walks 0 -> 30 -> 0 m, degrading
    and recovering the link while the incident unfolds;
  * **outage** — the edge box dies mid-incident: adaptive serving must
    detect the missed heartbeat, fail over on-glass, and resume from
    the versioned feature cache (<=1-step staleness asserted live by
    the runtime's every re-fusion).

Per regime, three placements over identical multi-session async-arrival
workloads: adaptive (the paper's Δt + t^e < t^g rule), always-on-glass,
always-edge. The comparison metric is cumulative serving latency
(sum over arrivals of emit - arrival on the simulated clock).

All engines are assembled through the unified factory
(``serving.api.build_engine``); the ``stream_over_tiered`` section runs
the ``"stream+tiered"`` composition — on-glass provisional partials
from cached (<=1-step stale) features while each offload is in flight —
that the pre-unification sibling runtimes could not express.

Beyond the paper's two-tier pair, the N-tier sweeps exercise the
``tiers=("glass", "ph1", "edge64x")`` placement surface: the phone is a
near-field tether, the edge box sits behind distance-degraded NLOS
WiFi, decisions are contention-aware, and each fusion tail gets its own
placement (possibly a third host). A crash->failover->rejoin sweep
restarts the edge box mid-incident and checks latency recovers once the
rejoined tier re-warms its replica and is re-selected.

Acceptance (checked by ``--smoke``):
  * adaptive >= 1.9x over all-on-glass on the paper's close-range
    regimes (static 0/5/10 m and mobility);
  * adaptive never worse than the best forced placement (5% slack);
  * outage: >= 1 heartbeat-detected failover, every session still ends
    with a final prediction that matches the monolithic full forward;
  * composition: >= 1 glass partial emitted, partials match
    ``partial_forward`` on their subset, finals still match the
    monolithic full forward;
  * 3-tier: adaptive strictly beats the best single-remote static
    placement on >= 1 regime, finals bit-equal to the monolithic
    forward (atol 0);
  * rejoin: >= 1 failover and >= 1 rejoin, the outage measurably hurt,
    post-rejoin mean latency recovers to within 15% of a no-crash run
    of the same workload (window-for-window — the modality mix differs
    across windows), the rejoined tier is re-selected, finals stay
    bit-equal;
  * speculation: under mobility + mid-incident crash, speculative dual
    placement (cancel-on-commit racing) + mid-flight re-dispatch beats
    the PR 5 glass-failover baseline on p99 per-arrival latency, with
    ZERO duplicate cache commits and bit-equal finals;
  * chaos: a seeded crash/rejoin schedule over both remote tiers
    replays with bit-equal finals, <=1-step staleness, zero
    duplicate/stale commits, and >= 1 completed rejoin cycle;
  * quantized: on a low-uplink regime past the paper's sweep (28 m
    NLOS), joint precision+placement (``precision={"edge": "int8"}``)
    strictly beats the fp32 adaptive baseline, the packed features
    shipped on offloaded events weigh >= 3x less than their raw fp32
    form, an all-fp32 precision map replays bit-identically to the
    precision-off legacy path, and the int8 accuracy deltas on the
    Table-3 tasks are reported.

-> artifacts/BENCH_tiered.json
"""
from __future__ import annotations

import json
from pathlib import Path

import numpy as np

from . import common as C

ART = Path(__file__).resolve().parent / "artifacts"

SCENARIOS = ("text_first", "vitals_first", "scene_late")
PAPER_REGIMES = ("static_0m", "static_5m", "static_10m", "mobility")

# Per-submodule seconds on the Edge-4C host tier, at the paper's Fig. 8
# scale (text/speech encoder 0.08 s on Edge-4C -> 107/2.7 * 0.08 = 3.2 s
# on-glass, the paper's measured number; scene detector and vitals
# encoder in proportion). Deterministic by design: the live runtime's
# speedups measure placement + transport behavior, and must not inherit
# container-load noise the way a freshly measured profile would
# (``benchmarks/fig8_latency.py`` owns the measured-profile story).
PAPER_BASE = {"enc:text": 0.08, "enc:vitals": 0.01, "enc:scene": 0.05,
              "tail": 0.005, "full": 0.15}

# N-tier sweep: glasses local, the EMT's phone, and the big edge box
# (core.offload.TIER_FACTORS keys; first entry is the local host)
TIERS3 = ("glass", "ph1", "edge64x")


def _finals_match_full(eng, eps, want, *, atol=0.0):
    """Every session's last final prediction vs the monolithic forward.
    ``atol=0`` pins bit-equality — placement changes the clock, never
    the math."""
    for sid in eps:
        st = eng.sessions[sid]
        last = next((r for r in reversed(st.records)
                     if r.kind == "final" and r.outputs is not None), None)
        if last is None:
            return False
        for k in want:
            got = np.asarray(last.outputs[k])
            if atol == 0.0:
                if not np.array_equal(got, np.asarray(want[k])):
                    return False
            elif not np.allclose(got, want[k], atol=atol):
                return False
    return True


def _workload(n_sessions, seed=0, *, n_vitals=4, n_scene=2):
    from repro.core import async_episode
    return {f"s{i}": async_episode(SCENARIOS[i % len(SCENARIOS)],
                                   seed=seed + i, n_vitals=n_vitals,
                                   n_scene=n_scene)
            for i in range(n_sessions)}


def _traces(quick):
    from repro.core import BandwidthTrace, nlos_bandwidth
    dists = (0, 5, 10, 30) if quick else (0, 5, 10, 20, 30)
    regimes = {f"static_{d}m": BandwidthTrace.static(nlos_bandwidth(d))
               for d in dists}
    walk = list(np.linspace(0, 30, 11)) + list(np.linspace(30, 0, 11))
    regimes["mobility"] = BandwidthTrace.walk(walk, nlos_bandwidth,
                                              period=1.0)
    return regimes


def _run(splits, params, profile_table, trace, eps, payloads, *,
         force=None, crash_at=None, rejoin_at=None, schedule=None,
         spec="tiered", **kw):
    from repro.serving.api import build_engine
    eng = build_engine(splits, params, spec, profile=profile_table,
                       trace=trace, share_encoders=True, force=force,
                       max_history=None, **kw)
    eng.run_arrivals(eps, lambda sid, ev: payloads[ev.modality],
                     crash_at=crash_at, rejoin_at=rejoin_at,
                     schedule=schedule)
    return eng


def _quantized_accuracy(quick, seed=0):
    """Table-3 task metrics for a trained text+vitals model, fp32 vs
    the int8 sidecar pytree — the accuracy cost of serving the
    quantized tier, on the paper's protocol/medicine/quantity tasks."""
    from repro.data import synthetic_nemsis as D
    from repro.models.quantized import quantize_emsnet_params
    from repro.training import emsnet_trainer as ET
    tcfg = C.emsnet_cfg(quick, train=True)
    n, steps = (800, 80) if quick else (8000, 400)
    tr, _, te = D.splits(D.generate(tcfg, n, seed=seed))
    ld = D.loader(tr, 64, modalities=("text", "vitals"))
    params, _ = ET.train(tcfg, ld, modalities=("text", "vitals"),
                         steps=steps)
    m32 = ET.evaluate(params, tcfg, te, ("text", "vitals"))
    m8 = ET.evaluate(quantize_emsnet_params(params), tcfg, te,
                     ("text", "vitals"))
    return ({k: float(m32[k]) for k in m32},
            {k: float(m8[k]) for k in m8},
            {k: float(m8[k] - m32[k]) for k in m32})


def _summary(eng):
    return {
        "total_latency_s": eng.total_latency_s(),
        "makespan_s": eng.makespan_s(),
        "placements": eng.placement_counts(),
        "transport": eng.transport_stats(),
    }


def run(quick=True, *, n_sessions=None, smoke=False, seed=0):
    import jax
    from repro.core import ProfileTable, feature_sizes, horizon

    n_sessions = n_sessions or (3 if (smoke or quick) else 8)
    cfg = C.emsnet_cfg(quick or smoke)
    zoo_eps = _workload(n_sessions, seed=seed,
                        n_vitals=2 if smoke else 4,
                        n_scene=2 if smoke else 3)
    payloads = C.sample_payloads(cfg, seed=seed)

    from repro.core import emsnet_zoo, split
    zoo = emsnet_zoo(cfg)
    splits = {k: split(m) for k, m in zoo.items()}
    shared = zoo["text+vitals+scene"].init_fn(jax.random.PRNGKey(seed))
    params = {k: shared for k in zoo}
    full = splits["text+vitals+scene"]

    # paper-scale offline profile -> tier-scaled simulated clock
    base = dict(PAPER_BASE)
    table = ProfileTable(base=base)

    result = {"n_sessions": n_sessions,
              "arrivals": sum(len(e) for e in zoo_eps.values()),
              "profile_base_ms": {k: v * 1e3 for k, v in base.items()},
              # what one downlink message actually weighs, per modality
              "feature_bytes": feature_sizes(full, shared, payloads),
              "regimes": {}}

    # ---- bandwidth regimes: adaptive vs forced placements, live runtime
    for name, trace in _traces(quick or smoke).items():
        runs = {}
        for label, force in (("adaptive", None), ("all_glass", "glass"),
                             ("all_edge", "edge")):
            eng = _run(splits, params, table, trace, zoo_eps, payloads,
                       force=force)
            runs[label] = eng
        lat = {k: e.total_latency_s() for k, e in runs.items()}
        entry = {k: _summary(e) for k, e in runs.items()}
        entry["speedup_adaptive_vs_glass"] = float(lat["all_glass"]
                                                   / lat["adaptive"])
        entry["speedup_adaptive_vs_edge"] = float(lat["all_edge"]
                                                  / lat["adaptive"])
        entry["adaptive_not_worse_than_best_forced"] = bool(
            lat["adaptive"] <= min(lat["all_glass"], lat["all_edge"]) * 1.05)
        result["regimes"][name] = entry
        C.csv_row(f"tiered_{name}", lat["adaptive"] * 1e6,
                  f"glass={lat['all_glass']*1e3:.1f}ms;"
                  f"edge={lat['all_edge']*1e3:.1f}ms;"
                  f"speedup_vs_glass="
                  f"{entry['speedup_adaptive_vs_glass']:.2f}x")

    # ---- edge outage: crash mid-incident, heartbeat-detected failover.
    # The crash lands just before a mid-episode text/vitals arrival —
    # modalities that reliably offload under this regime — so the death
    # is guaranteed to catch an in-flight offload (exercising detection
    # + fallback), not just flip later decisions to glass.
    from repro.core import BandwidthTrace, merge_arrivals, nlos_bandwidth
    offloadable = [t for t, _sid, ev in merge_arrivals(zoo_eps)
                   if ev.modality in ("text", "vitals")]
    crash_at = float(offloadable[len(offloadable) // 2] - 1e-6
                     if offloadable else horizon(zoo_eps) / 2)
    outage = _run(splits, params, table,
                  BandwidthTrace.static(nlos_bandwidth(5.0)),
                  zoo_eps, payloads, crash_at=crash_at)
    glass_ref = result["regimes"]["static_5m"]["all_glass"]
    finals_ok, parity_ok = True, True
    want = full.full(shared, payloads)
    for sid in zoo_eps:
        st = outage.sessions[sid]
        last_final = next((r for r in reversed(st.records)
                           if r.kind == "final" and r.outputs is not None),
                          None)
        if last_final is None:
            finals_ok = False
            continue
        for k in want:
            if not np.allclose(last_final.outputs[k], want[k], atol=1e-5):
                parity_ok = False
    out_lat = outage.total_latency_s()
    result["outage"] = {
        "crash_at_s": crash_at,
        "detect_at_s": float(outage.detect_at),
        **_summary(outage),
        "edge_known_dead": bool(outage.edge_known_dead),
        "fallbacks": outage.fallback_count,
        "all_sessions_reached_final": bool(finals_ok),
        "final_matches_monolithic_full": bool(parity_ok),
        "speedup_vs_all_glass": float(glass_ref["total_latency_s"]
                                      / out_lat),
    }
    C.csv_row("tiered_outage", out_lat * 1e6,
              f"fallbacks={outage.fallback_count};"
              f"vs_glass={result['outage']['speedup_vs_all_glass']:.2f}x")

    # ---- stream x tiered composition (unified API exclusive): while an
    # offload is in flight, the glasses emit a provisional partial from
    # cached (<=1-step stale) features. Run on the 10 m regime, where
    # raw-payload-heavy uplinks make the edge round trip slower than the
    # on-glass tail — the regime provisional partials exist for.
    from repro.core import BandwidthTrace as _BT
    from repro.models import emsnet as E
    comp = _run(splits, params, table,
                _BT.static(nlos_bandwidth(10.0)), zoo_eps, payloads,
                spec="stream+tiered")
    partials_ok, comp_finals_ok = True, True
    leads = []
    for r in comp.records:
        gp = r.glass_partial
        if gp is None:
            continue
        leads.append(r.t_emit - gp.t_emit)
        want_p = E.partial_forward(shared, cfg, payloads,
                                   list(gp.modalities))
        for k in want_p:
            if not np.allclose(gp.outputs[k], want_p[k], atol=1e-5):
                partials_ok = False
    for sid in zoo_eps:
        st = comp.sessions[sid]
        last_final = next((r for r in reversed(st.records)
                           if r.kind == "final" and r.outputs is not None),
                          None)
        if last_final is None:
            comp_finals_ok = False
            continue
        for k in want:
            if not np.allclose(last_final.outputs[k], want[k], atol=1e-5):
                comp_finals_ok = False
    pos = [l for l in leads if l > 0]
    result["stream_over_tiered"] = {
        "regime": "static_10m",
        **_summary(comp),
        "n_glass_partials": len(leads),
        "partials_match_partial_forward": bool(partials_ok),
        "finals_match_monolithic_full": bool(comp_finals_ok),
        "provisional_lead_ms": {
            "n_positive": len(pos),
            "mean_positive": float(np.mean(pos) * 1e3) if pos else 0.0,
            "max": float(max(leads) * 1e3) if leads else 0.0,
        },
    }
    C.csv_row("tiered_stream_composition", comp.total_latency_s() * 1e6,
              f"partials={len(leads)};lead_pos={len(pos)};"
              f"parity={partials_ok and comp_finals_ok}")

    # ---- N-tier placement (glass / phone / edge box): the phone rides
    # in the EMT's pocket (near-field tether), the edge box sits by the
    # manpack behind distance-degraded NLOS WiFi. Adaptive placement
    # may mix tiers per submodule (contention-aware decisions + tail
    # placement are the N-tier default); the static ablations pin
    # everything to one host. Gate: adaptive strictly beats the best
    # single-remote static on >= 1 regime, finals stay bit-equal to
    # the monolithic forward.
    ph_near = BandwidthTrace.static(nlos_bandwidth(0.0))
    result["tiers3"] = {}
    for name, edge_d in (("edge_far", 25.0), ("edge_near", 5.0)):
        edge_tr = BandwidthTrace.static(nlos_bandwidth(edge_d))
        runs3 = {label: _run(splits, params, table, edge_tr, zoo_eps,
                             payloads, force=force, tiers=TIERS3,
                             tier_traces={"ph1": ph_near})
                 for label, force in (("adaptive", None),
                                      ("all_glass", "glass"),
                                      ("all_ph1", "ph1"),
                                      ("all_edge64x", "edge64x"))}
        lat3 = {k: e.total_latency_s() for k, e in runs3.items()}
        best_static = min(lat3["all_ph1"], lat3["all_edge64x"])
        entry = {k: _summary(e) for k, e in runs3.items()}
        entry["adaptive_tail_placements"] = \
            runs3["adaptive"].tail_placement_counts()
        entry["speedup_adaptive_vs_glass"] = float(lat3["all_glass"]
                                                   / lat3["adaptive"])
        entry["speedup_adaptive_vs_best_static_remote"] = float(
            best_static / lat3["adaptive"])
        entry["adaptive_beats_best_static"] = bool(lat3["adaptive"]
                                                   < best_static)
        entry["finals_match_full_atol0"] = _finals_match_full(
            runs3["adaptive"], zoo_eps, want)
        result["tiers3"][name] = entry
        C.csv_row(f"tiered3_{name}", lat3["adaptive"] * 1e6,
                  f"ph1={lat3['all_ph1']*1e3:.1f}ms;"
                  f"edge64x={lat3['all_edge64x']*1e3:.1f}ms;"
                  f"vs_best_static="
                  f"{entry['speedup_adaptive_vs_best_static_remote']:.2f}x")

    # contention ablation: same far-edge regime, double the concurrent
    # sessions — queue-aware decisions spread load across tiers instead
    # of stampeding the fastest one
    eps_big = _workload(n_sessions * 2, seed=seed + 100,
                        n_vitals=2 if smoke else 4,
                        n_scene=2 if smoke else 3)
    edge_tr = BandwidthTrace.static(nlos_bandwidth(25.0))
    cont = {label: _run(splits, params, table, edge_tr, eps_big, payloads,
                        tiers=TIERS3, tier_traces={"ph1": ph_near},
                        contention_aware=aware)
            for label, aware in (("aware", True), ("blind", False))}
    result["tiers3"]["contention"] = {
        "sessions": n_sessions * 2,
        "aware_total_latency_s": cont["aware"].total_latency_s(),
        "blind_total_latency_s": cont["blind"].total_latency_s(),
        "aware_placements": cont["aware"].placement_counts(),
        "blind_placements": cont["blind"].placement_counts(),
        "aware_not_worse": bool(cont["aware"].total_latency_s()
                                <= cont["blind"].total_latency_s() * 1.05),
    }
    C.csv_row("tiered3_contention",
              cont["aware"].total_latency_s() * 1e6,
              f"blind={cont['blind'].total_latency_s()*1e3:.1f}ms")

    # ---- crash -> failover -> rejoin: the edge box dies mid-incident
    # and restarts later; a rejoined tier re-warms its replica from the
    # glass cache and must be re-selected, with latency recovering to
    # the pre-crash regime
    eps_long = _workload(n_sessions, seed=seed + 7,
                         n_vitals=6 if smoke else 10,
                         n_scene=3 if smoke else 5)
    span = horizon(eps_long)
    tc, tr = 0.4 * span, 0.7 * span
    mk_rj = lambda **kw: _run(  # noqa: E731
        splits, params, table, BandwidthTrace.static(nlos_bandwidth(5.0)),
        eps_long, payloads, tiers=TIERS3, tier_traces={"ph1": ph_near},
        **kw)
    rj = mk_rj(crash_at=tc, rejoin_at=tr)
    nocrash = mk_rj()               # same workload, the edge never dies
    mean_ms = lambda rs: (float(np.mean([r.latency_s for r in rs])) * 1e3  # noqa: E731
                          if rs else 0.0)
    window = lambda recs, lo, hi: [r for r in recs  # noqa: E731
                                   if lo <= r.t_arrival < hi]
    # the modality mix differs per window, so recovery compares each
    # window against the SAME window of the no-crash run rather than
    # against a differently-composed earlier window
    wins = {}
    for name_w, lo, hi in (("pre_crash", 0.0, tc), ("outage", tc, tr),
                           ("post_rejoin", tr, float("inf"))):
        wins[name_w] = {
            "n": len(window(rj.records, lo, hi)),
            "mean_ms": mean_ms(window(rj.records, lo, hi)),
            "no_crash_mean_ms": mean_ms(window(nocrash.records, lo, hi)),
        }
    post = window(rj.records, tr, float("inf"))
    post_rejoined = sum(1 for r in post
                        if "edge64x" in (r.enc_tier, r.tail_tier))
    recovered = bool(post and wins["post_rejoin"]["mean_ms"]
                     <= wins["post_rejoin"]["no_crash_mean_ms"] * 1.15)
    result["rejoin"] = {
        "crash_at_s": tc, "rejoin_at_s": tr,
        "rejoins": rj.rejoin_count, "fallbacks": rj.fallback_count,
        "windows": wins,
        "post_rejoin_events_on_rejoined_tier": post_rejoined,
        "outage_hurt": bool(wins["outage"]["mean_ms"]
                            > wins["outage"]["no_crash_mean_ms"]),
        "recovered_to_no_crash_latency": recovered,
        "finals_match_full_atol0": _finals_match_full(rj, eps_long, want),
        **_summary(rj),
    }
    C.csv_row("tiered3_rejoin", rj.total_latency_s() * 1e6,
              f"pre={wins['pre_crash']['mean_ms']:.1f}ms;"
              f"outage={wins['outage']['mean_ms']:.1f}ms;"
              f"post={wins['post_rejoin']['mean_ms']:.1f}ms;"
              f"rejoins={rj.rejoin_count}")

    # ---- speculative dual placement + mid-flight re-dispatch: the
    # robustness rung over PR 5's glass-failover. Same mobility walk +
    # mid-incident edge crash, two engines over the identical workload:
    #   baseline — PR 5 behavior (detect stall, lost flights re-run
    #              on-glass);
    #   robust   — deadline-pressured arrivals race glass vs the best
    #              remote (cancel-on-commit), and flights lost to the
    #              crash re-dispatch to the next-best surviving remote.
    # Gate: robust p99 per-arrival latency strictly beats baseline,
    # with ZERO duplicate cache commits and bit-equal finals — the
    # hedge may never pay in correctness.
    from repro.core.offload import SpeculationPolicy
    spec_pol = SpeculationPolicy(deadline_s=0.35, margin_s=0.05)
    walk = list(np.linspace(0, 30, 11)) + list(np.linspace(30, 0, 11))
    mob_tr = BandwidthTrace.walk(walk, nlos_bandwidth, period=1.0)
    offl2 = [t for t, _sid, ev in merge_arrivals(eps_long)
             if ev.modality in ("text", "vitals")]
    tc2 = float(offl2[len(offl2) // 2] - 1e-6 if offl2
                else horizon(eps_long) / 2)
    mk_sp = lambda **kw: _run(  # noqa: E731
        splits, params, table, mob_tr, eps_long, payloads, tiers=TIERS3,
        tier_traces={"ph1": ph_near}, crash_at=tc2, **kw)
    base_fo = mk_sp()                                   # PR 5 baseline
    rob = mk_sp(speculation=spec_pol, redispatch=True)
    p99 = lambda e: float(np.percentile(  # noqa: E731
        [r.latency_s for r in e.records], 99) * 1e3)
    fb_ms = lambda e: (float(np.mean(  # noqa: E731
        [r.latency_s for r in e.records if r.fallback]) * 1e3)
        if any(r.fallback for r in e.records) else 0.0)

    # race win-rates per bandwidth regime (no crash): how often does
    # each side of the hedge actually win?
    win_rates = {}
    for rname, rtr in (("static_5m",
                        BandwidthTrace.static(nlos_bandwidth(5.0))),
                       ("static_30m",
                        BandwidthTrace.static(nlos_bandwidth(30.0))),
                       ("mobility", mob_tr)):
        se = _run(splits, params, table, rtr, zoo_eps, payloads,
                  tiers=TIERS3, tier_traces={"ph1": ph_near},
                  speculation=spec_pol)
        win_rates[rname] = {
            "races": se.spec_count,
            "wins": dict(se.spec_wins),
            "glass_win_rate": (se.spec_wins.get("glass", 0)
                               / se.spec_count if se.spec_count else 0.0),
            "cancelled_msgs": se.fabric.cancelled_msgs(),
            "duplicate_commits": se.cache.duplicate_commits,
        }

    ss = rob.speculation_stats()
    result["speculation"] = {
        "regime": "mobility", "crash_at_s": tc2,
        "deadline_ms": spec_pol.deadline_s * 1e3,
        "margin_ms": spec_pol.margin_s * 1e3,
        "baseline_failover": {
            "p99_ms": p99(base_fo), "fallbacks": base_fo.fallback_count,
            "fallback_mean_ms": fb_ms(base_fo), **_summary(base_fo)},
        "robust": {
            "p99_ms": p99(rob), "fallbacks": rob.fallback_count,
            "fallback_mean_ms": fb_ms(rob), "races": rob.spec_count,
            "race_wins": dict(rob.spec_wins),
            "crash_saves": rob.spec_crash_saves,
            "redispatches": rob.redispatch_count,
            "cancelled_msgs": ss["cancelled_msgs"],
            "duplicate_commits": ss["duplicate_commits"],
            "stale_commits": ss["stale_commits"], **_summary(rob)},
        "race_win_rates": win_rates,
        "finals_match_full_atol0": _finals_match_full(rob, eps_long,
                                                      want),
    }
    result["passed_speculation_beats_failover"] = bool(
        p99(rob) < p99(base_fo)
        and ss["duplicate_commits"] == 0 and ss["stale_commits"] == 0
        and result["speculation"]["finals_match_full_atol0"])
    C.csv_row("tiered_speculation", rob.total_latency_s() * 1e6,
              f"p99={p99(rob):.1f}ms;base_p99={p99(base_fo):.1f}ms;"
              f"races={rob.spec_count};redispatch={rob.redispatch_count}")

    # ---- chaos schedule: seeded repeated crash/rejoin cycles over BOTH
    # remote tiers, replayed through the same engine. Gate: every final
    # stays bit-equal, the <=1-step staleness invariant holds at end
    # state, zero duplicate/stale commits, and the schedule actually
    # cycled (>= 1 rejoin).
    from repro.serving.chaos import chaos_schedule
    sched = chaos_schedule(seed + 13, horizon=span,
                           tiers=("ph1", "edge64x"),
                           mean_up_s=0.35 * span, mean_down_s=0.15 * span,
                           min_up_s=0.1 * span, min_down_s=0.05 * span)
    chaos_eng = _run(splits, params, table,
                     BandwidthTrace.static(nlos_bandwidth(5.0)),
                     eps_long, payloads, tiers=TIERS3,
                     tier_traces={"ph1": ph_near}, schedule=sched,
                     speculation=spec_pol, redispatch=True)
    stale_ok = True
    for sid in eps_long:
        st = chaos_eng.sessions[sid]
        for m, step in st.input_step.items():
            e = chaos_eng.cache.peek(sid, m)
            if e is None or step - e.step > 1:
                stale_ok = False
    css = chaos_eng.speculation_stats()
    result["chaos"] = {
        "seed": seed + 13, "events": len(sched),
        "schedule": [{"tier": e.tier, "crash_at": e.crash_at,
                      "rejoin_at": e.rejoin_at} for e in sched],
        "rejoins": chaos_eng.rejoin_count,
        "fallbacks": chaos_eng.fallback_count,
        "redispatches": chaos_eng.redispatch_count,
        "races": chaos_eng.spec_count,
        "duplicate_commits": css["duplicate_commits"],
        "stale_commits": css["stale_commits"],
        "staleness_le_1": bool(stale_ok),
        "finals_match_full_atol0": _finals_match_full(chaos_eng,
                                                      eps_long, want),
        **_summary(chaos_eng),
    }
    result["passed_chaos"] = bool(
        result["chaos"]["finals_match_full_atol0"] and stale_ok
        and css["duplicate_commits"] == 0 and css["stale_commits"] == 0
        and chaos_eng.rejoin_count >= 1)
    # full registry snapshot of the hardest run in the file (chaos
    # schedule + speculation + re-dispatch over three tiers)
    result["metrics"] = chaos_eng.metrics_snapshot()
    C.csv_row("tiered_chaos", chaos_eng.total_latency_s() * 1e6,
              f"events={len(sched)};rejoins={chaos_eng.rejoin_count};"
              f"redispatch={chaos_eng.redispatch_count};"
              f"parity={result['chaos']['finals_match_full_atol0']}")

    # ---- quantized glass tier: bytes-aware precision+placement
    # co-decision on a low-uplink regime past the paper's sweep (28 m
    # NLOS — feature transport dominates the offload decision, the
    # regime quantization exists for). Three adaptive runs over the
    # identical workload:
    #   fp32     — today's path, precision off (the legacy engine);
    #   fp32_map — precision={"edge": "fp32"}: an all-fp32 map DISARMS
    #              the precision rung, so this pins the bit-identity
    #              contract at benchmark scale;
    #   int8     — precision={"edge": "int8"}: the policy enumerates
    #              (tier, precision) jointly, offloaded encoders run
    #              the int8 sidecar params, features ship packed.
    from repro.core import payload_nbytes
    from repro.models.quantized import quantize_feature
    low_tr = BandwidthTrace.static(nlos_bandwidth(28.0))
    qruns = {lbl: _run(splits, params, table, low_tr, zoo_eps, payloads,
                       **kw)
             for lbl, kw in (("fp32", {}),
                             ("fp32_map", {"precision": {"edge": "fp32"}}),
                             ("int8", {"precision": {"edge": "int8"}}))}
    qlat = {k: e.total_latency_s() for k, e in qruns.items()}
    legacy_ok = (
        qlat["fp32"] == qlat["fp32_map"]
        and [(r.tier, r.t_emit, r.precision) for r in qruns["fp32"].records]
        == [(r.tier, r.t_emit, r.precision)
            for r in qruns["fp32_map"].records]
        and all(np.array_equal(a.outputs[k], b.outputs[k])
                for a, b in zip(qruns["fp32"].records,
                                qruns["fp32_map"].records)
                if a.outputs is not None and b.outputs is not None
                for k in a.outputs))
    # feature-transport weight per offloaded event: raw fp32 feature
    # vs the packed form actually shipped. Encoders are deterministic
    # per modality payload, so the per-event wire weight is exact —
    # and it is payload_nbytes on the real arrays, the same rule the
    # transport charges with.
    raw_feats = {m: full.encoders[m](shared, payloads[m])
                 for m in full.modalities()}
    packed_nb = {m: payload_nbytes(quantize_feature(f))
                 for m, f in raw_feats.items()}
    off = [r for r in qruns["int8"].records
           if r.enc_tier not in (None, "glass")]
    off8 = [r for r in off if r.precision == "int8"]
    raw_b = sum(payload_nbytes(raw_feats[r.modality]) for r in off8)
    packed_b = sum(packed_nb[r.modality] for r in off8)
    shrink = (raw_b / packed_b) if packed_b else 0.0
    q_finals_ok = all(
        any(r.kind == "final" and r.outputs is not None
            for r in qruns["int8"].sessions[sid].records)
        for sid in zoo_eps)
    acc32, acc8, acc_d = _quantized_accuracy(quick or smoke, seed)
    result["quantized"] = {
        "regime": "low_uplink_28m",
        **{k: _summary(e) for k, e in qruns.items()},
        "offloaded_events": len(off),
        "offloaded_int8_events": len(off8),
        "feature_bytes_raw": {m: payload_nbytes(f)
                              for m, f in raw_feats.items()},
        "feature_bytes_packed": packed_nb,
        "offloaded_feature_bytes": {"fp32": raw_b, "int8": packed_b,
                                    "shrink_x": shrink},
        "legacy_bit_identical_with_precision_off": bool(legacy_ok),
        "all_sessions_reached_final": bool(q_finals_ok),
        "table3_accuracy_fp32": acc32,
        "table3_accuracy_int8": acc8,
        "table3_accuracy_deltas": acc_d,
    }
    result["passed_quantized_transport"] = bool(
        qlat["int8"] < qlat["fp32"]
        and len(off8) >= 1
        and shrink >= 3.0
        and legacy_ok and q_finals_ok)
    C.csv_row("tiered_quantized", qlat["int8"] * 1e6,
              f"fp32={qlat['fp32']*1e3:.1f}ms;shrink={shrink:.2f}x;"
              f"int8_offloads={len(off8)};"
              f"d_protocol_top1={acc_d['protocol_top1']:+.3f}")

    # ---- acceptance
    paper_speedups = {r: result["regimes"][r]["speedup_adaptive_vs_glass"]
                      for r in PAPER_REGIMES if r in result["regimes"]}
    result["paper_regime_speedups_vs_glass"] = paper_speedups
    result["passed_speedup_1p9x"] = all(s >= 1.9
                                        for s in paper_speedups.values())
    result["passed_adaptive_not_worse"] = all(
        e["adaptive_not_worse_than_best_forced"]
        for e in result["regimes"].values())
    result["passed_outage_recovery"] = (
        outage.edge_known_dead and outage.fallback_count >= 1
        and finals_ok and parity_ok)
    result["passed_stream_composition"] = bool(
        len(leads) >= 1 and partials_ok and comp_finals_ok)
    three = [e for e in result["tiers3"].values()
             if "adaptive_beats_best_static" in e]
    result["passed_3tier_beats_static"] = (
        any(e["adaptive_beats_best_static"] for e in three)
        and all(e["finals_match_full_atol0"] for e in three))
    result["passed_rejoin_recovery"] = bool(
        result["rejoin"]["rejoins"] >= 1
        and result["rejoin"]["fallbacks"] >= 1
        and result["rejoin"]["outage_hurt"]
        and result["rejoin"]["recovered_to_no_crash_latency"]
        and result["rejoin"]["post_rejoin_events_on_rejoined_tier"] >= 1
        and result["rejoin"]["finals_match_full_atol0"])

    ART.mkdir(parents=True, exist_ok=True)
    (ART / "BENCH_tiered.json").write_text(json.dumps(result, indent=2))

    if smoke:
        failed = [k for k in ("passed_speedup_1p9x",
                              "passed_adaptive_not_worse",
                              "passed_outage_recovery",
                              "passed_stream_composition",
                              "passed_3tier_beats_static",
                              "passed_rejoin_recovery",
                              "passed_speculation_beats_failover",
                              "passed_chaos",
                              "passed_quantized_transport")
                  if not result[k]]
        if failed:
            raise SystemExit(f"tiered acceptance failed: {failed}; "
                             f"speedups={paper_speedups}")
    return result


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--sessions", type=int, default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="small run + assert >=1.9x and crash recovery")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    r = run(quick=not args.full, n_sessions=args.sessions, smoke=args.smoke)
    print(json.dumps(r, indent=2))
