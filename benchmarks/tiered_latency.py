"""Fig. 15-style offloading speedups from the LIVE tiered runtime.

``benchmarks/fig15_offloading.py`` replays the paper's offloading
comparison through the closed-form decision rule; this benchmark runs
the actual ``TieredEMSServe`` split-serving runtime instead — live
per-event decisions through the heartbeat monitor, byte-accounted
feature transport on in-order links, per-tier busy clocks — and sweeps
the paper's bandwidth regimes:

  * **static_{0,5,10,20,30}m** — scenario 2: NLOS WiFi at a fixed
    glass<->edge distance;
  * **mobility** — scenario 3: the EMT walks 0 -> 30 -> 0 m, degrading
    and recovering the link while the incident unfolds;
  * **outage** — the edge box dies mid-incident: adaptive serving must
    detect the missed heartbeat, fail over on-glass, and resume from
    the versioned feature cache (<=1-step staleness asserted live by
    the runtime's every re-fusion).

Per regime, three placements over identical multi-session async-arrival
workloads: adaptive (the paper's Δt + t^e < t^g rule), always-on-glass,
always-edge. The comparison metric is cumulative serving latency
(sum over arrivals of emit - arrival on the simulated clock).

All engines are assembled through the unified factory
(``serving.api.build_engine``); the ``stream_over_tiered`` section runs
the ``"stream+tiered"`` composition — on-glass provisional partials
from cached (<=1-step stale) features while each offload is in flight —
that the pre-unification sibling runtimes could not express.

Acceptance (checked by ``--smoke``):
  * adaptive >= 1.9x over all-on-glass on the paper's close-range
    regimes (static 0/5/10 m and mobility);
  * adaptive never worse than the best forced placement (5% slack);
  * outage: >= 1 heartbeat-detected failover, every session still ends
    with a final prediction that matches the monolithic full forward;
  * composition: >= 1 glass partial emitted, partials match
    ``partial_forward`` on their subset, finals still match the
    monolithic full forward.

-> artifacts/BENCH_tiered.json
"""
from __future__ import annotations

import json
from pathlib import Path

import numpy as np

from . import common as C

ART = Path(__file__).resolve().parent / "artifacts"

SCENARIOS = ("text_first", "vitals_first", "scene_late")
PAPER_REGIMES = ("static_0m", "static_5m", "static_10m", "mobility")

# Per-submodule seconds on the Edge-4C host tier, at the paper's Fig. 8
# scale (text/speech encoder 0.08 s on Edge-4C -> 107/2.7 * 0.08 = 3.2 s
# on-glass, the paper's measured number; scene detector and vitals
# encoder in proportion). Deterministic by design: the live runtime's
# speedups measure placement + transport behavior, and must not inherit
# container-load noise the way a freshly measured profile would
# (``benchmarks/fig8_latency.py`` owns the measured-profile story).
PAPER_BASE = {"enc:text": 0.08, "enc:vitals": 0.01, "enc:scene": 0.05,
              "tail": 0.005, "full": 0.15}


def _workload(n_sessions, seed=0, *, n_vitals=4, n_scene=2):
    from repro.core import async_episode
    return {f"s{i}": async_episode(SCENARIOS[i % len(SCENARIOS)],
                                   seed=seed + i, n_vitals=n_vitals,
                                   n_scene=n_scene)
            for i in range(n_sessions)}


def _traces(quick):
    from repro.core import BandwidthTrace, nlos_bandwidth
    dists = (0, 5, 10, 30) if quick else (0, 5, 10, 20, 30)
    regimes = {f"static_{d}m": BandwidthTrace.static(nlos_bandwidth(d))
               for d in dists}
    walk = list(np.linspace(0, 30, 11)) + list(np.linspace(30, 0, 11))
    regimes["mobility"] = BandwidthTrace.walk(walk, nlos_bandwidth,
                                              period=1.0)
    return regimes


def _run(splits, params, profile_table, trace, eps, payloads, *,
         force=None, crash_at=None, spec="tiered"):
    from repro.serving.api import build_engine
    eng = build_engine(splits, params, spec, profile=profile_table,
                       trace=trace, share_encoders=True, force=force,
                       max_history=None)
    eng.run_arrivals(eps, lambda sid, ev: payloads[ev.modality],
                     crash_at=crash_at)
    return eng


def _summary(eng):
    return {
        "total_latency_s": eng.total_latency_s(),
        "makespan_s": eng.makespan_s(),
        "placements": eng.placement_counts(),
        "transport": eng.transport_stats(),
    }


def run(quick=True, *, n_sessions=None, smoke=False, seed=0):
    import jax
    from repro.core import ProfileTable, feature_sizes, horizon

    n_sessions = n_sessions or (3 if (smoke or quick) else 8)
    cfg = C.emsnet_cfg(quick or smoke)
    zoo_eps = _workload(n_sessions, seed=seed,
                        n_vitals=2 if smoke else 4,
                        n_scene=2 if smoke else 3)
    payloads = C.sample_payloads(cfg, seed=seed)

    from repro.core import emsnet_zoo, split
    zoo = emsnet_zoo(cfg)
    splits = {k: split(m) for k, m in zoo.items()}
    shared = zoo["text+vitals+scene"].init_fn(jax.random.PRNGKey(seed))
    params = {k: shared for k in zoo}
    full = splits["text+vitals+scene"]

    # paper-scale offline profile -> tier-scaled simulated clock
    base = dict(PAPER_BASE)
    table = ProfileTable(base=base)

    result = {"n_sessions": n_sessions,
              "arrivals": sum(len(e) for e in zoo_eps.values()),
              "profile_base_ms": {k: v * 1e3 for k, v in base.items()},
              # what one downlink message actually weighs, per modality
              "feature_bytes": feature_sizes(full, shared, payloads),
              "regimes": {}}

    # ---- bandwidth regimes: adaptive vs forced placements, live runtime
    for name, trace in _traces(quick or smoke).items():
        runs = {}
        for label, force in (("adaptive", None), ("all_glass", "glass"),
                             ("all_edge", "edge")):
            eng = _run(splits, params, table, trace, zoo_eps, payloads,
                       force=force)
            runs[label] = eng
        lat = {k: e.total_latency_s() for k, e in runs.items()}
        entry = {k: _summary(e) for k, e in runs.items()}
        entry["speedup_adaptive_vs_glass"] = float(lat["all_glass"]
                                                   / lat["adaptive"])
        entry["speedup_adaptive_vs_edge"] = float(lat["all_edge"]
                                                  / lat["adaptive"])
        entry["adaptive_not_worse_than_best_forced"] = bool(
            lat["adaptive"] <= min(lat["all_glass"], lat["all_edge"]) * 1.05)
        result["regimes"][name] = entry
        C.csv_row(f"tiered_{name}", lat["adaptive"] * 1e6,
                  f"glass={lat['all_glass']*1e3:.1f}ms;"
                  f"edge={lat['all_edge']*1e3:.1f}ms;"
                  f"speedup_vs_glass="
                  f"{entry['speedup_adaptive_vs_glass']:.2f}x")

    # ---- edge outage: crash mid-incident, heartbeat-detected failover.
    # The crash lands just before a mid-episode text/vitals arrival —
    # modalities that reliably offload under this regime — so the death
    # is guaranteed to catch an in-flight offload (exercising detection
    # + fallback), not just flip later decisions to glass.
    from repro.core import BandwidthTrace, merge_arrivals, nlos_bandwidth
    offloadable = [t for t, _sid, ev in merge_arrivals(zoo_eps)
                   if ev.modality in ("text", "vitals")]
    crash_at = float(offloadable[len(offloadable) // 2] - 1e-6
                     if offloadable else horizon(zoo_eps) / 2)
    outage = _run(splits, params, table,
                  BandwidthTrace.static(nlos_bandwidth(5.0)),
                  zoo_eps, payloads, crash_at=crash_at)
    glass_ref = result["regimes"]["static_5m"]["all_glass"]
    finals_ok, parity_ok = True, True
    want = full.full(shared, payloads)
    for sid in zoo_eps:
        st = outage.sessions[sid]
        last_final = next((r for r in reversed(st.records)
                           if r.kind == "final" and r.outputs is not None),
                          None)
        if last_final is None:
            finals_ok = False
            continue
        for k in want:
            if not np.allclose(last_final.outputs[k], want[k], atol=1e-5):
                parity_ok = False
    out_lat = outage.total_latency_s()
    result["outage"] = {
        "crash_at_s": crash_at,
        "detect_at_s": float(outage.detect_at),
        **_summary(outage),
        "edge_known_dead": bool(outage.edge_known_dead),
        "fallbacks": outage.fallback_count,
        "all_sessions_reached_final": bool(finals_ok),
        "final_matches_monolithic_full": bool(parity_ok),
        "speedup_vs_all_glass": float(glass_ref["total_latency_s"]
                                      / out_lat),
    }
    C.csv_row("tiered_outage", out_lat * 1e6,
              f"fallbacks={outage.fallback_count};"
              f"vs_glass={result['outage']['speedup_vs_all_glass']:.2f}x")

    # ---- stream x tiered composition (unified API exclusive): while an
    # offload is in flight, the glasses emit a provisional partial from
    # cached (<=1-step stale) features. Run on the 10 m regime, where
    # raw-payload-heavy uplinks make the edge round trip slower than the
    # on-glass tail — the regime provisional partials exist for.
    from repro.core import BandwidthTrace as _BT
    from repro.models import emsnet as E
    comp = _run(splits, params, table,
                _BT.static(nlos_bandwidth(10.0)), zoo_eps, payloads,
                spec="stream+tiered")
    partials_ok, comp_finals_ok = True, True
    leads = []
    for r in comp.records:
        gp = r.glass_partial
        if gp is None:
            continue
        leads.append(r.t_emit - gp.t_emit)
        want_p = E.partial_forward(shared, cfg, payloads,
                                   list(gp.modalities))
        for k in want_p:
            if not np.allclose(gp.outputs[k], want_p[k], atol=1e-5):
                partials_ok = False
    for sid in zoo_eps:
        st = comp.sessions[sid]
        last_final = next((r for r in reversed(st.records)
                           if r.kind == "final" and r.outputs is not None),
                          None)
        if last_final is None:
            comp_finals_ok = False
            continue
        for k in want:
            if not np.allclose(last_final.outputs[k], want[k], atol=1e-5):
                comp_finals_ok = False
    pos = [l for l in leads if l > 0]
    result["stream_over_tiered"] = {
        "regime": "static_10m",
        **_summary(comp),
        "n_glass_partials": len(leads),
        "partials_match_partial_forward": bool(partials_ok),
        "finals_match_monolithic_full": bool(comp_finals_ok),
        "provisional_lead_ms": {
            "n_positive": len(pos),
            "mean_positive": float(np.mean(pos) * 1e3) if pos else 0.0,
            "max": float(max(leads) * 1e3) if leads else 0.0,
        },
    }
    C.csv_row("tiered_stream_composition", comp.total_latency_s() * 1e6,
              f"partials={len(leads)};lead_pos={len(pos)};"
              f"parity={partials_ok and comp_finals_ok}")

    # ---- acceptance
    paper_speedups = {r: result["regimes"][r]["speedup_adaptive_vs_glass"]
                      for r in PAPER_REGIMES if r in result["regimes"]}
    result["paper_regime_speedups_vs_glass"] = paper_speedups
    result["passed_speedup_1p9x"] = all(s >= 1.9
                                        for s in paper_speedups.values())
    result["passed_adaptive_not_worse"] = all(
        e["adaptive_not_worse_than_best_forced"]
        for e in result["regimes"].values())
    result["passed_outage_recovery"] = (
        outage.edge_known_dead and outage.fallback_count >= 1
        and finals_ok and parity_ok)
    result["passed_stream_composition"] = bool(
        len(leads) >= 1 and partials_ok and comp_finals_ok)

    ART.mkdir(parents=True, exist_ok=True)
    (ART / "BENCH_tiered.json").write_text(json.dumps(result, indent=2))

    if smoke:
        failed = [k for k in ("passed_speedup_1p9x",
                              "passed_adaptive_not_worse",
                              "passed_outage_recovery",
                              "passed_stream_composition")
                  if not result[k]]
        if failed:
            raise SystemExit(f"tiered acceptance failed: {failed}; "
                             f"speedups={paper_speedups}")
    return result


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--sessions", type=int, default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="small run + assert >=1.9x and crash recovery")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    r = run(quick=not args.full, n_sessions=args.sessions, smoke=args.smoke)
    print(json.dumps(r, indent=2))
