"""Paper Table 3: 2-modal EMSNet vs SOTA unimodal baselines on D1,
tasks 1-3 (protocol top-1/3/5, medicine top-1/3/5, quantity
mse/pearson/spearman). Synthetic NEMSIS-schema data; the reproduced
claim is directional: multimodal > each unimodal baseline.
"""
from __future__ import annotations

import time

import numpy as np

from . import common as C


def _fmt(m):
    return (f"P={m['protocol_top1']:.2f}/{m['protocol_top3']:.2f}/"
            f"{m['protocol_top5']:.2f};M={m['medicine_top1']:.2f}/"
            f"{m['medicine_top3']:.2f}/{m['medicine_top5']:.2f};"
            f"Q={m['quantity_mse']:.2f}/{m['quantity_pearsonr']:.2f}/"
            f"{m['quantity_spearmanr']:.2f}")


def run(quick=True):
    from repro.data import synthetic_nemsis as D
    from repro.training import emsnet_trainer as ET

    cfg = C.emsnet_cfg(quick, train=True)
    n = 2000 if quick else 20000
    steps = 120 if quick else 600
    d1 = D.generate(cfg, n, seed=0)
    tr, _, te = D.splits(d1)
    rows = []
    results = {}
    combos = [("text",), ("vitals",), ("text", "vitals")]
    for mods in combos:
        t0 = time.time()
        ld = D.loader(tr, 64, modalities=mods)
        params, _ = ET.train(cfg, ld, modalities=mods, steps=steps)
        m = ET.evaluate(params, cfg, te, mods)
        results[mods] = m
        name = "table3_" + "+".join(mods)
        rows.append(C.csv_row(name, (time.time() - t0) * 1e6, _fmt(m)))
    # directional reproduction of Table 3
    mm = results[("text", "vitals")]
    for uni in (("text",), ("vitals",)):
        assert mm["protocol_top1"] >= results[uni]["protocol_top1"] - 0.02, \
            f"multimodal should beat unimodal {uni}"
    return rows


if __name__ == "__main__":
    run(quick=False)
