"""Multi-session serving throughput: the unified engine's batch
construction (``serving.api.build_engine(..., "batch")``) vs looping
the per-event EMSServe (the paper's single-responder engine) over N
concurrent sessions.

Workload: every session streams an EMS episode — symptom text first
(paper Episode 1 ordering), then a vitals/scene mix whose vitals GROW
one timestep per vitals event (aggregate=concat). Growing streams are
the key property: the unbucketed per-event baseline meets a NEW input
shape (and takes a fresh XLA compile) every few events for the entire
life of an incident, while the bucketed engine's shape set is finite.

Protocol (production-faithful): both engines run the first
``warmup_ticks`` of every episode untimed — steady-state submodule
programs and every (modality, bucket, batch) shape get compiled there.
The timed window is the episode's continuation, where every vitals
stream is longer than anything in history: the batched engine must add
ZERO compiles there (the plateau criterion), while the baseline keeps
recompiling — exactly what it would do in deployment.

Reports (-> artifacts/BENCH_serving.json and CSV rows): sessions/sec
and events/sec for both engines + speedup, p50/p99 per-event latency
under the batched engine, XLA compile counts and the per-tick compile
trace over the timed window.

Ragged section (``result["ragged"]``, gated by ``passed_ragged``): the
concatenated ragged flush path over a shared-parameter zoo with the
segment-flash parity config on both sides. Three deterministic gates:
(a) bit parity — ragged predictions at the reference's own cadence are
``np.array_equal`` to the per-event unbucketed ``core.engine.EMSServe``;
(b) kernel calls — every coalesced flush issues at most one packed
encoder call per live modality plus ONE grouped tail (O(modalities)+1,
vs O(modalities x buckets)+O(subsets) bucketed); (c) padded-FLOP
fraction strictly below the bucketed baseline's on the same session
mix. The legacy baseline/batched sections run exactly as before
(ragged stays OFF there).

Observability section (``result["obs_overhead"]``, gated by
``passed_obs_overhead``): the same flush workload untraced (the
disabled-tracer default — i.e. the legacy engine, byte-identical
numbers) vs with a live ``repro.obs.Tracer`` recording every event's
lifecycle; enabled tracing must cost < 5% wall regression and the
resulting trace must replay cleanly through the invariant auditor.
``result["metrics"]`` embeds the batched engine's full metrics
registry snapshot.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np

from . import common as C

ART = Path(__file__).resolve().parent / "artifacts"

TEXT_LENS = (6, 12, 24, 31)       # per-session utterance lengths -> buckets


def _episodes(n_sessions, n_ticks, cfg, seed=0):
    """Deterministic prefix [text, vitals, scene] (so every modality,
    model, and bucket is exercised during warmup), then a seeded
    vitals/scene mix."""
    from repro.core.episodes import Event
    rng = np.random.default_rng(seed)
    eps, payloads = {}, {}
    for i in range(n_sessions):
        sid = f"s{i}"
        kinds = ["text", "vitals", "scene"] + rng.choice(
            ["vitals", "scene"], size=max(0, n_ticks - 3), p=(0.55, 0.45)
        ).tolist()
        eps[sid] = [Event(t, k, float(t)) for t, k in enumerate(kinds[:n_ticks])]
        text_len = min(TEXT_LENS[i % len(TEXT_LENS)], cfg.max_text_len)
        p = C.sample_payloads(cfg, seed=seed + i)
        payloads[sid] = {
            "text": p["text"][:, :text_len],
            "vitals": p["vitals"][:, :1],          # ONE new timestep per event
            "scene": p["scene"],
        }
    return eps, payloads


def _aggregate(old, new):
    """Vitals extend the time series; other modalities replace."""
    import jax.numpy as jnp
    if old is not None and new.ndim == 3:
        return jnp.concatenate([old, new], axis=1)
    return new


def _pctl(xs, q):
    return float(np.percentile(np.asarray(xs), q)) if len(xs) else 0.0


def _ragged_section(n_sessions, n_ticks, seed=1):
    """The ragged-vs-bucketed comparison on its own shared-parameter
    zoo (the grouped tail requires one parameter pytree) with the
    segment-flash bit-parity config on BOTH sides."""
    import jax

    from repro.core import EMSServe, emsnet_zoo, split
    from repro.serving.api import build_engine

    cfg = C.emsnet_cfg(True, text_encoder="microbert", vocab_size=512,
                       max_text_len=16, vitals_hidden=32,
                       use_flash_text=True, flash_segments=True)
    zoo = emsnet_zoo(cfg)
    splits = {k: split(m) for k, m in zoo.items()}
    shared = zoo["text+vitals+scene"].init_fn(jax.random.PRNGKey(0))
    params = {k: shared for k in zoo}
    eps, payloads = _episodes(n_sessions, n_ticks, cfg, seed=seed)

    def payload_fn(sid, ev):
        return payloads[sid][ev.modality]

    # --- gate (a): bit parity at the reference's own per-event cadence
    refs = {sid: EMSServe(splits, params, cached=True, real_time=True,
                          session=sid) for sid in eps}
    eng = build_engine(splits, params, "batch+stream",
                       share_encoders=True, ragged=True,
                       deadline_s=0.0, max_history=None)
    bitwise, max_diff, n_compared = True, 0.0, 0
    for t in range(n_ticks):
        for sid, events in eps.items():
            if t >= len(events):
                continue
            ev = events[t]
            rec = refs[sid].on_event(ev, payload_fn(sid, ev),
                                     aggregate=_aggregate)
            rep = eng.submit(sid, ev, payload_fn(sid, ev),
                             aggregate=_aggregate)
            if rec.recommendation is None:
                continue
            (pred,) = rep.predictions
            for k, want in rec.recommendation.items():
                got = np.asarray(pred.outputs[k])
                if not np.array_equal(got, np.asarray(want)):
                    bitwise = False
                    max_diff = max(max_diff,
                                   float(np.abs(got - np.asarray(want)).max()))
            n_compared += 1

    # --- gates (b)+(c): coalesced ragged vs bucketed, same session mix
    def coalesced(ragged):
        e = build_engine(splits, params, "batch+stream",
                         share_encoders=True, ragged=ragged,
                         deadline_s=None, batch_bucket_min=2,
                         max_history=None)
        for t in range(n_ticks):
            for sid, events in eps.items():
                if t < len(events):
                    e.submit(sid, events[t], payload_fn(sid, events[t]),
                             aggregate=_aggregate)
            e.flush()
        return e

    reng = coalesced(True)
    beng = coalesced(False)
    r_calls = [(f.n_encoder_calls, f.n_tail_calls) for f in reng.flushes]
    b_calls = [(f.n_encoder_calls, f.n_tail_calls) for f in beng.flushes]
    r_frac = float(np.mean([f.padded_flop_frac for f in reng.flushes]))
    b_frac = float(np.mean([f.padded_flop_frac for f in beng.flushes]))
    n_modalities = 3
    gates = {
        "passed_ragged_bit_parity": bool(bitwise and n_compared > 0),
        "passed_ragged_kernel_calls": bool(
            all(e <= n_modalities and tl <= 1 for e, tl in r_calls)
            and sum(e + tl for e, tl in r_calls)
            < sum(e + tl for e, tl in b_calls)),
        "passed_ragged_padded_flops": bool(r_frac < b_frac),
    }
    return {
        "config": {"text_encoder": cfg.text_encoder,
                   "use_flash_text": True, "flash_segments": True,
                   "n_sessions": n_sessions, "n_ticks": n_ticks},
        "bit_parity_vs_unbucketed_reference": {
            "predictions_compared": n_compared,
            "bitwise_equal_atol0": bool(bitwise),
            "max_abs_diff": max_diff,
        },
        "kernel_calls_per_flush": {
            "ragged": [list(c) for c in r_calls],
            "bucketed": [list(c) for c in b_calls],
            "ragged_total": sum(e + tl for e, tl in r_calls),
            "bucketed_total": sum(e + tl for e, tl in b_calls),
        },
        "padded_flop_frac": {"ragged": r_frac, "bucketed": b_frac},
        "packed_shapes": reng.ragged.n_shapes(),
        **gates,
        "passed_ragged": all(gates.values()),
    }


def _obs_overhead_section(n_sessions, n_ticks, warmup_ticks, repeats=5):
    """Traced-vs-untraced wall time on the SAME flush workload.

    The disabled tracer (the default every legacy path runs with) is a
    falsy no-op, so the untraced engine here IS the legacy engine. The
    traced engine records the full per-event lifecycle; its in-memory
    trace is replayed through the invariant auditor before timing is
    even considered a pass. Min-of-N repeats on each side, plus a
    small absolute slack so sub-100ms walls don't flap on scheduler
    noise.
    """
    from repro.core import Bucketer
    from repro.obs import Tracer, audit_tracer
    from repro.serving.api import build_engine

    cfg = C.emsnet_cfg(True)
    splits, params = C.build_split_models(cfg)
    eps, payloads = _episodes(n_sessions, n_ticks, cfg)
    max_buckets = {"vitals": 8, "text": cfg.max_text_len}

    def payload_fn(sid, ev):
        return payloads[sid][ev.modality]

    def one_run(tracer):
        eng = build_engine(splits, params, "batch",
                           bucketer=Bucketer(max_buckets=max_buckets),
                           batch_bucket_min=min(8, n_sessions),
                           max_history=None, tracer=tracer)

        def tick(t):
            for sid, events in eps.items():
                if t < len(events):
                    eng.submit(sid, events[t], payload_fn(sid, events[t]),
                               aggregate=_aggregate)
            eng.flush()

        for t in range(warmup_ticks):
            tick(t)
        t0 = time.perf_counter()
        for t in range(warmup_ticks, n_ticks):
            tick(t)
        return time.perf_counter() - t0, eng

    one_run(None)                   # shared-XLA-cache warmup pass
    untraced = min(one_run(None)[0] for _ in range(repeats))
    traced, eng = min((one_run(Tracer()) for _ in range(repeats)),
                      key=lambda we: we[0])
    audit = audit_tracer(eng.tracer)
    overhead = traced / untraced - 1.0
    passed_wall = bool(traced <= untraced * 1.05 + 0.02)
    return {
        "repeats": repeats,
        "untraced_wall_s": untraced,
        "traced_wall_s": traced,
        "overhead_frac": overhead,
        "trace_events": len(eng.tracer.events),
        "audit": {"ok": audit.ok, "violations": audit.violations[:5],
                  "checks": audit.checks},
        "passed_obs_wall": passed_wall,
        "passed_obs_audit": bool(audit.ok),
        "passed_obs_overhead": bool(passed_wall and audit.ok),
    }


def run(quick=True, *, n_sessions=None, n_ticks=None, warmup_ticks=4):
    from repro.core import Bucketer, EMSServe
    from repro.serving.api import build_engine

    n_sessions = n_sessions or (8 if quick else 32)
    n_ticks = n_ticks or (16 if quick else 48)
    if n_ticks <= warmup_ticks:
        raise SystemExit(f"--ticks must exceed the warmup window "
                         f"({warmup_ticks}); got {n_ticks}")
    cfg = C.emsnet_cfg(quick)
    # separate split sets so each engine has its own jit caches and the
    # reported compile counts are per-engine, not shared
    splits, params = C.build_split_models(cfg)
    splits_b, params_b = C.build_split_models(cfg)
    eps, payloads = _episodes(n_sessions, n_ticks, cfg)
    # vitals: sliding window of the 8 most recent samples (bounded
    # memory for an unbounded stream); text: padded to its bucket
    max_buckets = {"vitals": 8, "text": cfg.max_text_len}

    def payload_fn(sid, ev):
        return payloads[sid][ev.modality]

    # ------- baseline: loop the per-event engine, one session at a time
    base_wall = 0.0
    base_compiles_start = base_compiles_end = 0
    engines = {sid: EMSServe(splits, params, cached=True, real_time=True)
               for sid in eps}
    for sid, events in eps.items():                      # warmup window
        for ev in events[:warmup_ticks]:
            engines[sid].on_event(ev, payload_fn(sid, ev),
                                  aggregate=_aggregate)
    base_compiles_start = next(iter(engines.values())).compile_count()
    t0 = time.perf_counter()
    for sid, events in eps.items():                      # timed window
        for ev in events[warmup_ticks:]:
            engines[sid].on_event(ev, payload_fn(sid, ev),
                                  aggregate=_aggregate)
    base_wall = time.perf_counter() - t0
    base_compiles_end = next(iter(engines.values())).compile_count()
    n_timed_events = sum(len(ev) - warmup_ticks for ev in eps.values())

    # ------- batched, bucketed, dispatch-async engine (unified API)
    beng = build_engine(splits_b, params_b, "batch",
                        bucketer=Bucketer(max_buckets=max_buckets),
                        batch_bucket_min=min(8, n_sessions),
                        max_history=None)

    def tick(t):
        for sid, events in eps.items():
            if t < len(events):
                beng.submit(sid, events[t], payload_fn(sid, events[t]),
                            aggregate=_aggregate)
        beng.flush()

    for t in range(warmup_ticks):                        # warmup window
        tick(t)
    warm_flushes = len(beng.flushes)
    compile_trace = [beng.compile_count()]
    t0 = time.perf_counter()
    for t in range(warmup_ticks, n_ticks):               # timed window
        tick(t)
        compile_trace.append(beng.compile_count())
    batch_wall = time.perf_counter() - t0

    lats = [lat for f in beng.flushes[warm_flushes:]
            for lat in f.latencies.values()]
    result = {
        "n_sessions": n_sessions,
        "n_ticks": n_ticks,
        "warmup_ticks": warmup_ticks,
        "timed_events": n_timed_events,
        "baseline": {
            "wall_s": base_wall,
            "sessions_per_s": n_sessions / base_wall,
            "events_per_s": n_timed_events / base_wall,
            "xla_compiles_during_timed": base_compiles_end - base_compiles_start,
            "xla_compiles_total": base_compiles_end,
        },
        "batched": {
            "wall_s": batch_wall,
            "sessions_per_s": n_sessions / batch_wall,
            "events_per_s": n_timed_events / batch_wall,
            "xla_compiles_during_timed": compile_trace[-1] - compile_trace[0],
            "xla_compiles_total": compile_trace[-1],
            "p50_event_latency_ms": _pctl(lats, 50) * 1e3,
            "p99_event_latency_ms": _pctl(lats, 99) * 1e3,
            "encoder_calls": sum(f.n_encoder_calls for f in beng.flushes),
            "tail_calls": sum(f.n_tail_calls for f in beng.flushes),
        },
        "speedup": base_wall / batch_wall,
        "compile_trace_timed": compile_trace,
        "buckets": {f"{m}:{b}": n
                    for (m, b), n in sorted(beng.bucketer.histogram.items())},
    }

    # ------- ragged grouped flush path (own zoo; legacy paths above
    # ran with ragged OFF and are byte-for-byte what they always were)
    result["ragged"] = _ragged_section(n_sessions, n_ticks)

    # ------- observability: registry snapshot + tracing overhead gate
    result["metrics"] = beng.metrics_snapshot()
    result["obs_overhead"] = _obs_overhead_section(
        n_sessions, n_ticks, warmup_ticks)

    ART.mkdir(parents=True, exist_ok=True)
    (ART / "BENCH_serving.json").write_text(json.dumps(result, indent=2))

    C.csv_row("serve_batched_per_session", batch_wall / n_sessions * 1e6,
              f"sessions_per_s={result['batched']['sessions_per_s']:.2f};"
              f"speedup={result['speedup']:.2f}x;"
              f"compiles_timed={result['batched']['xla_compiles_during_timed']}")
    C.csv_row("serve_baseline_per_session", base_wall / n_sessions * 1e6,
              f"sessions_per_s={result['baseline']['sessions_per_s']:.2f};"
              f"compiles_timed={result['baseline']['xla_compiles_during_timed']}")
    C.csv_row("serve_event_latency_p99",
              result["batched"]["p99_event_latency_ms"] * 1e3,
              f"p50_ms={result['batched']['p50_event_latency_ms']:.2f}")
    rg = result["ragged"]
    C.csv_row("serve_ragged_padded_flop_frac",
              rg["padded_flop_frac"]["ragged"] * 1e6,
              f"bucketed={rg['padded_flop_frac']['bucketed']:.3f};"
              f"kernel_calls={rg['kernel_calls_per_flush']['ragged_total']}"
              f"vs{rg['kernel_calls_per_flush']['bucketed_total']};"
              f"bitwise={rg['bit_parity_vs_unbucketed_reference']['bitwise_equal_atol0']}")
    if not rg["passed_ragged"]:
        failed = [k for k, v in rg.items()
                  if k.startswith("passed_") and not v]
        raise SystemExit(f"ragged gates failed: {failed}")
    obs = result["obs_overhead"]
    C.csv_row("serve_obs_overhead", obs["overhead_frac"] * 1e6,
              f"untraced_s={obs['untraced_wall_s']:.3f};"
              f"traced_s={obs['traced_wall_s']:.3f};"
              f"events={obs['trace_events']};"
              f"audit_ok={obs['audit']['ok']}")
    if not obs["passed_obs_overhead"]:
        failed = [k for k, v in obs.items()
                  if k.startswith("passed_") and not v]
        raise SystemExit(f"obs overhead gates failed: {failed} "
                         f"(overhead {obs['overhead_frac']:+.1%}, "
                         f"audit {obs['audit']})")
    return result


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--sessions", type=int, default=None)
    ap.add_argument("--ticks", type=int, default=None)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    r = run(quick=not args.full, n_sessions=args.sessions,
            n_ticks=args.ticks)
    print(json.dumps(r, indent=2))
