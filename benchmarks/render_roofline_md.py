"""Render EXPERIMENTS.md roofline tables from dry-run artifacts.

Replaces the <!-- ROOFLINE_BASELINE --> / <!-- ROOFLINE_TUNED --> markers.
  PYTHONPATH=src python -m benchmarks.render_roofline_md
"""
from __future__ import annotations

import json
import re
from pathlib import Path

ART = Path(__file__).resolve().parent / "artifacts" / "dryrun"
EXP = Path(__file__).resolve().parents[1] / "EXPERIMENTS.md"

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def fmt(v):
    return f"{v:.3g}"


def table(tuned: bool) -> str:
    rows = ["| arch | shape | t_compute | t_memory | t_collective | bound | useful | strategy |",
            "|---|---|---|---|---|---|---|---|"]
    recs = {}
    for p in ART.glob("*.json"):
        r = json.loads(p.read_text())
        if not r.get("ok") or r["mesh"] != "single":
            continue
        if bool(r.get("tuned")) != tuned:
            continue
        key = (r["arch"], r["shape"])
        if key in recs:   # several tuned variants: keep the best bound
            def bound(x):
                ro = x["roofline"]
                return max(ro["t_compute_s"], ro["t_memory_s"],
                           ro["t_collective_s"])
            if bound(r) >= bound(recs[key]):
                continue
        recs[key] = r
    for (arch, shape) in sorted(recs, key=lambda k: (k[0], SHAPE_ORDER.index(k[1]))):
        r = recs[(arch, shape)]
        ro = r["roofline"]
        rows.append(
            f"| {arch} | {shape} | {fmt(ro['t_compute_s'])} s | "
            f"{fmt(ro['t_memory_s'])} s | {fmt(ro['t_collective_s'])} s | "
            f"{ro['bottleneck']} | {ro['useful_flops_ratio']:.2f} | "
            f"{r.get('strategy', '2d')} |")
    return "\n".join(rows)


def main():
    text = EXP.read_text()
    text = re.sub(r"<!-- ROOFLINE_BASELINE -->(\n\|[^\n]*)*",
                  "<!-- ROOFLINE_BASELINE -->\n" + table(False), text)
    text = re.sub(r"<!-- ROOFLINE_TUNED -->(\n\|[^\n]*)*",
                  "<!-- ROOFLINE_TUNED -->\n" + table(True), text)
    EXP.write_text(text)
    print("EXPERIMENTS.md roofline tables updated "
          f"({len(table(False).splitlines())-2} baseline rows, "
          f"{len(table(True).splitlines())-2} tuned rows)")


if __name__ == "__main__":
    main()
