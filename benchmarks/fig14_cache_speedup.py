"""Paper Figure 14 (scenario 1): cumulative inference time, EMSServe's
feature cache vs direct multimodal inference, over the three Table-6
episodes. The paper reports 1.9x-11.7x across four hardware tiers; here
the compute ratio is measured on this host and the tier spread comes
from the measured per-module profile (text-module cost dominates, so the
ratio grows with text-encoder size exactly as in the paper).
"""
from __future__ import annotations

import time

from . import common as C


def run(quick=True):
    from repro.core import EMSServe, profile, table6

    rows = []
    encoders = ["tinybert"] if quick else ["tinybert", "mobilebert", "bertbase"]
    for enc in encoders:
        cfg = C.emsnet_cfg(quick, text_encoder=enc)
        splits, params = C.build_split_models(cfg)
        payloads = C.sample_payloads(cfg)
        C.warmup_engine_models(splits, params, payloads)
        for ep_id, events in table6().items():
            times = {}
            for cached in (False, True):
                # 3 repetitions, keep the best (cold-start protection)
                best = float("inf")
                for _ in range(3):
                    eng = EMSServe(splits, params, cached=cached,
                                   real_time=True)
                    eng.run_episode(events, lambda ev: payloads[ev.modality])
                    best = min(best, eng.cumulative_time())
                times[cached] = best
            speedup = times[False] / times[True]
            rows.append(C.csv_row(
                f"fig14_ep{ep_id}_{enc}", times[True] * 1e6,
                f"direct_us={times[False]*1e6:.0f};speedup={speedup:.2f}x"))
    return rows


if __name__ == "__main__":
    run(quick=False)
