"""Shared helpers for the benchmark harness."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def emsnet_cfg(quick=True, *, train=False, **kw):
    from repro.configs.emsnet import EMSNetConfig
    base = dict(vocab_size=2048)
    if quick:
        base.update(max_text_len=32, vitals_len=16)
        if train:   # training benchmarks need a CPU-sized text encoder
            base.update(text_encoder="microbert", vocab_size=512,
                        max_text_len=16, vitals_hidden=32)
    base.update(kw)
    return EMSNetConfig(**base)


def build_split_models(cfg, seed=0):
    from repro.core import emsnet_module, split
    mods = {
        "m1": emsnet_module(cfg, ("text",)),
        "m2": emsnet_module(cfg, ("text", "vitals")),
        "m3": emsnet_module(cfg, ("text", "vitals", "scene")),
    }
    splits = {k: split(m) for k, m in mods.items()}
    key = jax.random.PRNGKey(seed)
    params = {k: m.init_fn(jax.random.fold_in(key, i))
              for i, (k, m) in enumerate(mods.items())}
    return splits, params


def sample_payloads(cfg, seed=0, batch=1):
    rng = np.random.default_rng(seed)
    return {
        "text": jnp.asarray(rng.integers(1, cfg.vocab_size,
                                         (batch, cfg.max_text_len)), jnp.int32),
        "vitals": jnp.asarray(rng.normal(size=(batch, cfg.vitals_len,
                                               cfg.n_vitals)), jnp.float32),
        "scene": jnp.asarray(rng.integers(0, 2, (batch, cfg.scene_dim)),
                             jnp.float32),
    }


def warmup_engine_models(splits, params, payloads):
    """Compile every jitted submodule before timing."""
    for name, sm in splits.items():
        feats = {}
        for m in sm.modalities():
            feats[m] = sm.encoders[m](params[name], payloads[m])
        jax.block_until_ready(sm.tail(params[name], feats))
        jax.block_until_ready(
            sm.full(params[name], {m: payloads[m] for m in sm.modalities()}))


def bench(fn, *args, iters=10):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def csv_row(name, us_per_call, derived=""):
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)
    return (name, us_per_call, derived)
