"""Paper Figure 8: per-component inference time across hardware tiers.

Base times are measured on this host; the four tiers (Edge-64X, Edge-4C,
PH1, Google Glass) are derived with the paper's measured slowdown
factors, reproducing the profile table that drives offloading decisions.
Also verifies the paper's structural findings: text modules dominate,
vitals modules are orders of magnitude cheaper than text.
"""
from __future__ import annotations

from . import common as C


def run(quick=True):
    from repro.core import profile
    from repro.core.offload import TIER_FACTORS, ProfileTable

    rows = []
    text_encoders = ["tinybert"] if quick else ["tinybert", "mobilebert"]
    for enc in text_encoders:
        for venc in (("gru",) if quick else ("rnn", "gru", "lstm")):
            cfg = C.emsnet_cfg(quick, text_encoder=enc, vitals_encoder=venc)
            splits, params = C.build_split_models(cfg)
            payloads = C.sample_payloads(cfg)
            base = profile(splits["m3"], params["m3"], payloads, iters=5)
            table = ProfileTable(base=base)
            for sub, t in base.items():
                tiers = ";".join(
                    f"{tier}={table.time(sub, tier)*1e3:.2f}ms"
                    for tier in TIER_FACTORS)
                rows.append(C.csv_row(f"fig8_{enc}-{venc}_{sub}", t * 1e6, tiers))
            # structural claims
            assert base["enc:text"] > 5 * base["enc:vitals"], \
                "text module must dominate vitals (paper Insight 2)"
    return rows


if __name__ == "__main__":
    run(quick=False)
