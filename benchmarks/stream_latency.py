"""Streaming async-modality serving: time-to-first vs time-to-final
prediction, against the per-event complete-event baseline.

Workload: N concurrent sessions, each an ``async_episode`` — modalities
onset at different times per the lag scenarios (text-first radio
transcript, vitals-first monitor hookup, scene-late camera), cycled
across sessions. Arrivals from all sessions interleave in global
episode-time order, exactly what an edge box at one incident sees.

Two serving disciplines over the SAME arrivals, measured on the same
serving clock the per-event engine uses (``core.engine.EMSServe``:
``clock = max(clock, arrival_time) + compute``) — serving can never run
ahead of data availability, and compute is the measured wall time of
the actual jitted calls:

  * **StreamingEMSServe** (subset-model zoo, shared parameter pytree,
    ``share_encoders=True``): every arrival immediately yields a
    partial-modality prediction; later arrivals re-fuse cached features
    (zero encoder re-runs) until the prediction is final.
    Time-to-first-prediction (TTFP) is the serving-clock time of a
    session's FIRST (partial) prediction; time-to-final (TTF) of its
    first all-modality prediction.
  * **Per-event baseline** (full model only, driven by
    ``core.engine.EMSServe`` records over the same interleaved arrival
    stream): a session shows NOTHING until its complete event set (all
    three modalities) has arrived and been fused. Time-to-complete is
    the serving-clock time of that first complete-event prediction.

Both runs are warmed (all XLA programs compiled) before timing, so the
comparison is steady-state serving, not compilation.

Acceptance (checked by ``--smoke``): at >= 4 concurrent sessions, mean
streaming TTFP is strictly below the baseline's mean time-to-complete.

-> artifacts/BENCH_streaming.json
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np

from . import common as C

ART = Path(__file__).resolve().parent / "artifacts"

SCENARIOS = ("text_first", "vitals_first", "scene_late")
TEXT_LENS = (6, 12, 24, 31)


def _workload(n_sessions, cfg, seed=0, *, n_vitals=4, n_scene=2):
    from repro.core import async_episode
    eps, payloads = {}, {}
    base = C.sample_payloads(cfg, seed=seed)
    for i in range(n_sessions):
        sid = f"s{i}"
        eps[sid] = async_episode(SCENARIOS[i % len(SCENARIOS)], seed=seed + i,
                                 n_vitals=n_vitals, n_scene=n_scene)
        text_len = min(TEXT_LENS[i % len(TEXT_LENS)], cfg.max_text_len)
        p = C.sample_payloads(cfg, seed=seed + 1 + i)
        payloads[sid] = {
            "text": p["text"][:, :text_len],
            "vitals": p["vitals"][:, :5],
            "scene": p["scene"],
        }
    return eps, payloads


def _arrivals(eps):
    from repro.core import merge_arrivals
    return merge_arrivals(eps)


def _stats(xs):
    xs = np.asarray(list(xs), float)
    return {"mean_ms": float(xs.mean() * 1e3),
            "p50_ms": float(np.percentile(xs, 50) * 1e3),
            "max_ms": float(xs.max() * 1e3)}


def _stream_engine(cfg, splits, params, n_sessions):
    from repro.core import Bucketer
    from repro.serving.api import build_engine
    return build_engine(
        splits, params, "batch+stream", share_encoders=True,
        deadline_s=0.0,
        bucketer=Bucketer(max_buckets={"vitals": 8,
                                       "text": cfg.max_text_len}),
        batch_bucket_min=min(8, n_sessions))


def _run_stream(cfg, splits, params, eps, payloads, n_sessions):
    """Flush per arrival; a shared serving clock gates each flush on the
    arrival's episode time (identical accounting to EMSServe)."""
    eng = _stream_engine(cfg, splits, params, n_sessions)
    # deadline handled manually so the clock sees each arrival
    eng.deadline_s = None
    clock, wall = 0.0, 0.0
    ttfp, ttf = {}, {}
    for t, sid, ev in _arrivals(eps):
        eng.submit(sid, ev, payloads[sid][ev.modality])
        rep = eng.flush()
        clock = max(clock, t) + rep.wall_s
        wall += rep.wall_s
        for p in rep.predictions:
            if p.sid not in ttfp:
                ttfp[p.sid] = clock
            if p.kind == "final" and p.sid not in ttf:
                ttf[p.sid] = clock
    return eng, wall, ttfp, ttf


def _run_baseline(cfg, splits_full, params_full, eps, payloads):
    """Per-event engines over the full model only, fed the same
    interleaved arrival stream on one shared serving clock: nothing is
    shown for a session until its complete event set has arrived."""
    from repro.core import EMSServe
    engines = {sid: EMSServe(splits_full, params_full,
                             cached=True, real_time=True)
               for sid in eps}
    complete = {}
    clock, wall = 0.0, 0.0
    for t, sid, ev in _arrivals(eps):
        rec = engines[sid].on_event(ev, payloads[sid][ev.modality])
        clock = max(clock, t) + rec.total_s
        wall += rec.total_s
        if rec.recommendation is not None and sid not in complete:
            complete[sid] = clock
    return engines, wall, complete


def run(quick=True, *, n_sessions=None, smoke=False, seed=0):
    from repro.core import emsnet_zoo, split
    import jax

    n_sessions = n_sessions or (4 if (smoke or quick) else 16)
    cfg = C.emsnet_cfg(quick or smoke)
    eps, payloads = _workload(n_sessions, cfg, seed=seed,
                              n_vitals=2 if smoke else 4,
                              n_scene=2 if smoke else 3)

    # streaming: subset zoo over ONE shared parameter pytree
    zoo = emsnet_zoo(cfg)
    splits = {k: split(m) for k, m in zoo.items()}
    shared = zoo["text+vitals+scene"].init_fn(jax.random.PRNGKey(seed))
    params = {k: shared for k in zoo}
    # baseline: the full model only, its own jit caches
    splits_full = {"full": split(zoo["text+vitals+scene"])}
    params_full = {"full": shared}

    # ---- warmup both paths (compile every program), then time fresh runs
    _run_stream(cfg, splits, params, eps, payloads, n_sessions)
    _run_baseline(cfg, splits_full, params_full, eps, payloads)

    eng, s_wall, ttfp, ttf = _run_stream(cfg, splits, params, eps,
                                         payloads, n_sessions)
    _bengines, b_wall, complete = _run_baseline(cfg, splits_full,
                                                params_full, eps, payloads)

    ttfp_s, ttf_s, comp_s = _stats(ttfp.values()), _stats(ttf.values()), \
        _stats(complete.values())
    result = {
        "n_sessions": n_sessions,
        "scenarios": [SCENARIOS[i % len(SCENARIOS)]
                      for i in range(n_sessions)],
        "arrivals_total": eng.events_total,
        "stream": {
            "wall_s": s_wall,
            "time_to_first_prediction": ttfp_s,
            "time_to_final_prediction": ttf_s,
            "encoder_calls": eng.encoder_calls_total(),
            "tail_calls": eng.tail_calls_total(),
            "xla_compiles": eng.compile_count(),
            "flushes": eng.flushes_total,
        },
        "baseline": {
            "wall_s": b_wall,
            "time_to_complete_prediction": comp_s,
            "xla_compiles": next(iter(_bengines.values())).compile_count(),
        },
        "per_session": {
            sid: {"ttfp_ms": ttfp[sid] * 1e3, "ttfinal_ms": ttf[sid] * 1e3,
                  "baseline_complete_ms": complete[sid] * 1e3}
            for sid in sorted(eps)},
        "speedup_ttfp_vs_complete":
            comp_s["mean_ms"] / ttfp_s["mean_ms"],
        "passed_ttfp_below_baseline_complete":
            ttfp_s["mean_ms"] < comp_s["mean_ms"]
            and all(ttfp[sid] < complete[sid] for sid in eps),
        # full registry snapshot of the timed streaming engine
        # (counters / gauges / p50-p95-p99 latency histograms)
        "metrics": eng.metrics_snapshot(),
    }

    # the committed artifact is the QUICK-mode workload; a smoke run
    # (CI gate, smaller episodes) must not silently clobber it
    ART.mkdir(parents=True, exist_ok=True)
    name = "BENCH_streaming.smoke.json" if smoke else "BENCH_streaming.json"
    (ART / name).write_text(json.dumps(result, indent=2))

    C.csv_row("stream_ttfp_mean", ttfp_s["mean_ms"] * 1e3,
              f"ttfinal_mean_ms={ttf_s['mean_ms']:.2f};"
              f"speedup_vs_complete={result['speedup_ttfp_vs_complete']:.2f}x")
    C.csv_row("baseline_time_to_complete_mean", comp_s["mean_ms"] * 1e3,
              f"n_sessions={n_sessions}")

    if smoke and not result["passed_ttfp_below_baseline_complete"]:
        raise SystemExit(
            "streaming TTFP not below baseline time-to-complete: "
            f"{ttfp_s['mean_ms']:.2f} ms >= {comp_s['mean_ms']:.2f} ms")
    return result


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--sessions", type=int, default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="small run + assert TTFP < baseline complete")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    r = run(quick=not args.full, n_sessions=args.sessions, smoke=args.smoke)
    print(json.dumps(r, indent=2))
