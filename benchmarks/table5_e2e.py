"""Paper Table 5: end-to-end accuracy with modality-frontend noise.

The paper shows that plugging in real speech recognition (Whisper-s/m,
WER ~0.05) and object detection (YOLO11, mAP ~0.8) barely moves EMSNet's
end-to-end accuracy vs ground-truth inputs. We reproduce that with the
stub frontends: corrupt text tokens at the measured WER and flip scene
flags at the measured detector error rate, then compare.
"""
from __future__ import annotations

import time

import numpy as np

from . import common as C
from .table3_accuracy import _fmt


def corrupt(te, cfg, *, wer=0.056, det_err=0.2, seed=0):
    rng = np.random.default_rng(seed)
    ds = te.subset(np.arange(len(te)))
    mask = (ds.text > 0) & (rng.random(ds.text.shape) < wer)
    noise = rng.integers(5, cfg.vocab_size, ds.text.shape).astype(ds.text.dtype)
    ds.text[mask] = noise[mask]
    flip = rng.random(ds.scene.shape) < det_err
    ds.scene[flip] = 1.0 - ds.scene[flip]
    return ds


def run(quick=True):
    from repro.data import synthetic_nemsis as D
    from repro.training import emsnet_trainer as ET

    cfg = C.emsnet_cfg(quick, train=True)
    n = 2000 if quick else 8000
    steps = 150 if quick else 500
    d2 = D.generate(cfg, n, seed=7, modal3=True)
    tr, _, te = D.splits(d2)
    mods = ("text", "vitals", "scene")
    params, _ = ET.train(cfg, D.loader(tr, 64), modalities=mods, steps=steps)

    rows = []
    m_truth = ET.evaluate(params, cfg, te, mods)
    rows.append(C.csv_row("table5_truth_inputs", 0.0, _fmt(m_truth)))
    for name, wer, derr in (("whisper_s_yolo11n", 0.056, 0.2),
                            ("whisper_t_glass", 0.315, 0.2)):
        m = ET.evaluate(params, cfg, corrupt(te, cfg, wer=wer, det_err=derr),
                        mods)
        rows.append(C.csv_row(f"table5_{name}", 0.0, _fmt(m)))
        if wer < 0.1:
            # paper: accurate frontends don't degrade E2E accuracy
            assert m["protocol_top1"] > m_truth["protocol_top1"] - 0.08
    return rows


if __name__ == "__main__":
    run(quick=False)
