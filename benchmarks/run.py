"""Benchmark harness: one module per paper table/figure + roofline.

Prints ``name,us_per_call,derived`` CSV. Default is quick mode (CPU
container-friendly); ``--full`` uses paper-scale settings.

  PYTHONPATH=src python -m benchmarks.run [--full] [--only fig14,...]
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

SUITES = ("fig8_latency", "fig14_cache_speedup", "fig15_offloading",
          "table3_accuracy", "table4_pmi", "table5_e2e", "serve_throughput",
          "stream_latency", "tiered_latency", "fleet_load", "kernels_bench",
          "roofline_report")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default="")
    args = ap.parse_args()
    only = [s.strip() for s in args.only.split(",") if s.strip()]

    print("name,us_per_call,derived")
    failures = []
    for suite in SUITES:
        if only and suite not in only:
            continue
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.{suite}", fromlist=["run"])
            mod.run(quick=not args.full)
            print(f"# {suite}: done in {time.time()-t0:.1f}s", flush=True)
        except Exception as e:  # noqa: BLE001
            failures.append(suite)
            print(f"# {suite}: FAILED {type(e).__name__}: {e}", flush=True)
            traceback.print_exc()
    if failures:
        sys.exit(f"benchmark failures: {failures}")


if __name__ == "__main__":
    main()
